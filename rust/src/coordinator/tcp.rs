//! Minimal TCP line protocol in front of the coordinator: one query per
//! line in, one JSON object per line out. `cft-rag serve --port N`.
//! The full wire format — request lines, control lines, and every reply
//! field — is specified in `docs/PROTOCOL.md`; this module is its
//! backend-side implementation (the router front door in `router/`
//! speaks the same lines).
//!
//! Protocol extras beyond plain queries (all parsed by
//! [`parse_control`]; the `\x01` prefix keeps control lines out of the
//! natural-language query space):
//!
//! * `:quit` closes the connection.
//! * [`STATS_REQUEST`] (`\x01stats`) returns the coordinator's
//!   [`MetricsSnapshot`](crate::coordinator::MetricsSnapshot) as one
//!   JSON line — the shard router's health prober uses it to observe
//!   backend *load*, and it is handy for single-node ops too.
//! * [`INSERT_REQUEST`] (`\x01insert <tree> <node> <entity…>`) and
//!   [`DELETE_REQUEST`] (`\x01delete <entity…>`) apply dynamic
//!   entity-index point updates (paper §5 / Algorithm 2) through
//!   [`Coordinator::update_entity`] / [`Coordinator::remove_entity`],
//!   replying `{"ok":…,"applied":…}` — the ack the router's replicated
//!   write path counts against its quorum.
//! * Elastic-membership lines (`router/rebalance.rs` drives these):
//!   [`DUMP_REQUEST`] (`\x01dump <entity…>`) reads a key's indexed
//!   addresses off a current replica, [`REPARTITION_REQUEST`]
//!   (`\x01repartition <epoch> <replicas> <index> <addr,…>`) installs
//!   the next membership epoch's [`KeyPartition`] on a live backend,
//!   and [`PURGE_REQUEST`] (`\x01purge`) runs the incumbents'
//!   disowned-key drop pass. [`JOIN_REQUEST`]/[`DRAIN_REQUEST`] are
//!   **router front-door** verbs; a backend answers them `ok:false`.
//!   The `\x01stats` payload carries `partition_epoch`, which the
//!   router's prober matches before (re-)admitting a backend.
//!
//! [`KeyPartition`]: crate::rag::config::KeyPartition
//!
//! Serving comes in three lifetimes: [`serve`] (runs until the process
//! dies — the CLI path), [`serve_with_shutdown`], which returns a
//! [`ServeHandle`] whose `shutdown()` stops the accept loop and joins
//! it — so tests (the router's especially) can start and stop real TCP
//! backends in-process without leaking listeners — and
//! [`serve_listener`], the pre-bound-listener form: a key-partitioned
//! fleet must fix every backend's address *before* any index is built,
//! so callers bind all listeners first, build each coordinator with its
//! [`KeyPartition`](crate::rag::config::KeyPartition), then serve.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::coordinator::server::Coordinator;
use crate::error::Result;
use crate::util::json::Json;
use crate::util::log;

/// Reserved control line: a client sending exactly this line receives
/// the coordinator's metrics snapshot as a JSON line instead of a query
/// reply.
pub const STATS_REQUEST: &str = "\x01stats";

/// Control-line verb for dynamic entity-index inserts:
/// `\x01insert <tree> <node> <entity…>` (the entity name is the greedy
/// tail — names contain spaces). See `docs/PROTOCOL.md`.
pub const INSERT_REQUEST: &str = "\x01insert";

/// Control-line verb for dynamic entity-index deletes:
/// `\x01delete <entity…>`. See `docs/PROTOCOL.md`.
pub const DELETE_REQUEST: &str = "\x01delete";

/// Control-line verb dumping an entity's indexed address list:
/// `\x01dump <entity…>` — the read half of the rebalancer's hinted
/// handoff (`router/rebalance.rs`). See `docs/PROTOCOL.md`.
pub const DUMP_REQUEST: &str = "\x01dump";

/// Control-line verb installing the next membership epoch's partition:
/// `\x01repartition <epoch> <replicas> <index> <addr,addr,…>`
/// (`replicas` 0 = full index). See `docs/PROTOCOL.md`.
pub const REPARTITION_REQUEST: &str = "\x01repartition";

/// Control-line verb for the incumbents' post-rebalance drop pass:
/// `\x01purge` reclaims every key the current partition no longer
/// owns. See `docs/PROTOCOL.md`.
pub const PURGE_REQUEST: &str = "\x01purge";

/// Router front-door verb: `\x01join <addr>` rebalances a new backend
/// into the serving ring. Backends reject it. See `docs/PROTOCOL.md`.
pub const JOIN_REQUEST: &str = "\x01join";

/// Router front-door verb: `\x01drain <addr>` hands a leaving
/// backend's keys off and removes it from the serving ring. Backends
/// reject it. See `docs/PROTOCOL.md`.
pub const DRAIN_REQUEST: &str = "\x01drain";

/// A parsed `\x01` control line (`docs/PROTOCOL.md` §Control lines).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ControlLine<'a> {
    /// `\x01stats` — metrics snapshot.
    Stats,
    /// `\x01insert <tree> <node> <entity…>` — register one occurrence.
    Insert { tree: u32, node: u32, entity: &'a str },
    /// `\x01delete <entity…>` — drop an entity from the index.
    Delete { entity: &'a str },
    /// `\x01dump <entity…>` — the entity's indexed addresses.
    Dump { entity: &'a str },
    /// `\x01repartition <epoch> <replicas> <index> <addr,addr,…>` —
    /// install the next membership epoch's key partition (`replicas`
    /// 0 clears it: full index).
    Repartition {
        epoch: u64,
        replicas: usize,
        index: usize,
        backends: &'a str,
    },
    /// `\x01purge` — drop every key the current partition disowns.
    Purge,
    /// `\x01join <addr>` — router front door: rebalance a backend in.
    Join { addr: &'a str },
    /// `\x01drain <addr>` — router front door: rebalance a backend out.
    Drain { addr: &'a str },
}

/// Parse a control line. Returns `None` when `line` is not a control
/// line at all (a plain query), and `Some(Err(reason))` for a malformed
/// or unknown one — the server answers those with `ok:false` rather
/// than treating binary junk as a natural-language query.
#[allow(clippy::type_complexity)]
pub fn parse_control(
    line: &str,
) -> Option<std::result::Result<ControlLine<'_>, String>> {
    let body = line.strip_prefix('\x01')?;
    let (verb, rest) = match body.split_once(' ') {
        Some((v, r)) => (v, r.trim()),
        None => (body, ""),
    };
    Some(match verb {
        "stats" if rest.is_empty() => Ok(ControlLine::Stats),
        "stats" => Err("\\x01stats takes no arguments".into()),
        "insert" => {
            let mut it = rest.splitn(3, ' ');
            let tree = it.next().unwrap_or("").parse::<u32>();
            let node = it.next().unwrap_or("").parse::<u32>();
            let entity = it.next().unwrap_or("").trim();
            match (tree, node) {
                (Ok(tree), Ok(node)) if !entity.is_empty() => {
                    Ok(ControlLine::Insert { tree, node, entity })
                }
                _ => Err(
                    "\\x01insert wants: <tree> <node> <entity...>".into()
                ),
            }
        }
        "delete" if !rest.is_empty() => {
            Ok(ControlLine::Delete { entity: rest })
        }
        "delete" => Err("\\x01delete wants: <entity...>".into()),
        "dump" if !rest.is_empty() => Ok(ControlLine::Dump { entity: rest }),
        "dump" => Err("\\x01dump wants: <entity...>".into()),
        "repartition" => {
            let mut it = rest.splitn(4, ' ');
            let epoch = it.next().unwrap_or("").parse::<u64>();
            let replicas = it.next().unwrap_or("").parse::<usize>();
            let index = it.next().unwrap_or("").parse::<usize>();
            let backends = it.next().unwrap_or("").trim();
            match (epoch, replicas, index) {
                (Ok(epoch), Ok(replicas), Ok(index))
                    if !backends.is_empty() =>
                {
                    Ok(ControlLine::Repartition {
                        epoch,
                        replicas,
                        index,
                        backends,
                    })
                }
                _ => Err("\\x01repartition wants: <epoch> <replicas> \
                          <index> <addr,addr,...>"
                    .into()),
            }
        }
        "purge" if rest.is_empty() => Ok(ControlLine::Purge),
        "purge" => Err("\\x01purge takes no arguments".into()),
        "join" if !rest.is_empty() => Ok(ControlLine::Join { addr: rest }),
        "join" => Err("\\x01join wants: <addr>".into()),
        "drain" if !rest.is_empty() => Ok(ControlLine::Drain { addr: rest }),
        "drain" => Err("\\x01drain wants: <addr>".into()),
        other => Err(format!("unknown control line {other:?}")),
    })
}

/// Serve until the process is killed. Each connection gets a thread;
/// queries are newline-delimited; responses are JSON lines.
pub fn serve(coordinator: Arc<Coordinator>, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    log::info!("cft-rag listening on {addr}");
    accept_loop(coordinator, listener, &AtomicBool::new(false));
    Ok(())
}

/// Bind `addr` and serve on a background thread; the returned handle
/// stops the listener on demand. Bind to port 0 for an ephemeral port
/// (the handle reports the resolved address).
pub fn serve_with_shutdown(
    coordinator: Arc<Coordinator>,
    addr: &str,
) -> Result<ServeHandle> {
    serve_listener(coordinator, TcpListener::bind(addr)?)
}

/// [`serve_with_shutdown`] over an **already-bound** listener. This is
/// how a key-partitioned fleet starts: every backend's address must be
/// known before any index is built (the partition hashes the address
/// list), so callers bind all N listeners first, then build each
/// coordinator with its partition, then hand the listeners here.
pub fn serve_listener(
    coordinator: Arc<Coordinator>,
    listener: TcpListener,
) -> Result<ServeHandle> {
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let thread = {
        let stop = stop.clone();
        std::thread::Builder::new()
            .name("cft-tcp-accept".into())
            .spawn(move || accept_loop(coordinator, listener, &stop))
            .expect("spawn accept loop")
    };
    log::info!("cft-rag listening on {local} (with shutdown handle)");
    Ok(ServeHandle { addr: local, stop, thread: Some(thread) })
}

/// Accept until `stop` is raised (checked after every accept outcome;
/// [`ServeHandle::shutdown`] raises it and then connects-to-self so a
/// blocked `accept()` wakes immediately).
fn accept_loop(
    coordinator: Arc<Coordinator>,
    listener: TcpListener,
    stop: &AtomicBool,
) {
    for stream in listener.incoming() {
        if stop.load(Ordering::Acquire) {
            // the wakeup (or a late client) connection is dropped
            // unserved; the listener closes when this frame returns
            break;
        }
        accept_one(&coordinator, stream);
    }
}

/// A running TCP front end that can be stopped.
pub struct ServeHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl ServeHandle {
    /// The bound address (resolved — useful after binding port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the accept thread. Connections already
    /// handed to handler threads drain on their own (they exit when the
    /// peer closes or `:quit`s); the listener socket itself is released
    /// before this returns, so the port can be rebound.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        let Some(thread) = self.thread.take() else { return };
        self.stop.store(true, Ordering::Release);
        // connect-to-self: unblocks an accept() with nothing inbound
        let _ = TcpStream::connect(self.addr);
        let _ = thread.join();
    }
}

impl Drop for ServeHandle {
    fn drop(&mut self) {
        // dropping the handle must not leak the listener thread
        self.stop_and_join();
    }
}

/// Handle one `accept()` outcome. Accept failures are *transient* from
/// the listener's point of view — a reset half-open connection
/// (`ECONNABORTED`), fd exhaustion (`EMFILE`), an interrupted syscall —
/// so they are logged and survived; the pre-PR-2 `stream?` turned any
/// one of them into the death of the whole listener.
fn accept_one(coordinator: &Arc<Coordinator>, stream: std::io::Result<TcpStream>) {
    match stream {
        Ok(stream) => {
            let c = coordinator.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(c, stream);
            });
        }
        Err(e) => {
            log::warn!("accept failed (transient; listener continues): {e}");
            // A *persistent* failure (e.g. EMFILE under fd exhaustion)
            // would otherwise hot-spin the accept loop at 100% CPU and
            // flood the log; a short pause bounds the retry rate while
            // still recovering as soon as the condition clears. EINTR
            // is the one kind where an immediate retry is always right.
            if e.kind() != std::io::ErrorKind::Interrupted {
                std::thread::sleep(std::time::Duration::from_millis(50));
            }
        }
    }
}

fn handle_conn(coordinator: Arc<Coordinator>, stream: TcpStream) -> std::io::Result<()> {
    let peer = stream.peer_addr()?;
    log::debug!("connection from {peer}");
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    for line in reader.lines() {
        let line = line?;
        if coordinator.is_stopped() {
            // behave like a dead process: close instead of answering —
            // a live `\x01stats` on a stopped backend would hide its
            // death from the router's health prober
            break;
        }
        let query = line.trim();
        if query.is_empty() {
            continue;
        }
        if query == ":quit" {
            break;
        }
        let reply = match parse_control(query) {
            Some(Ok(ControlLine::Stats)) => stats_reply(&coordinator),
            Some(Ok(ControlLine::Insert { tree, node, entity })) => {
                update_ack(coordinator.update_entity(entity, tree, node))
            }
            Some(Ok(ControlLine::Delete { entity })) => {
                update_ack(coordinator.remove_entity(entity))
            }
            Some(Ok(ControlLine::Dump { entity })) => {
                dump_reply(&coordinator, entity)
            }
            Some(Ok(ControlLine::Repartition {
                epoch,
                replicas,
                index,
                backends,
            })) => repartition_reply(
                &coordinator,
                epoch,
                replicas,
                index,
                backends,
            ),
            Some(Ok(ControlLine::Purge)) => match coordinator.drop_disowned()
            {
                Ok(n) => Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("dropped", Json::Num(n as f64)),
                ]),
                Err(e) => Json::obj(vec![
                    ("ok", Json::Bool(false)),
                    ("error", Json::Str(e.to_string())),
                ]),
            },
            Some(Ok(
                ControlLine::Join { .. } | ControlLine::Drain { .. },
            )) => Json::obj(vec![
                ("ok", Json::Bool(false)),
                (
                    "error",
                    Json::Str(
                        "join/drain are router front-door control lines; \
                         send them to the router, not a backend"
                            .into(),
                    ),
                ),
            ]),
            Some(Err(reason)) => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::Str(reason)),
            ]),
            None => respond(&coordinator, query),
        };
        writer.write_all(reply.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
    }
    Ok(())
}

/// The `\x01stats` payload: the coordinator's metrics snapshot stamped
/// with the backend's `partition_epoch` — what the router's health
/// prober matches against the serving ring's epoch before (re-)admitting
/// the backend.
fn stats_reply(coordinator: &Coordinator) -> Json {
    let mut json = coordinator.metrics().snapshot().to_json();
    if let Json::Obj(m) = &mut json {
        m.insert(
            "partition_epoch".into(),
            Json::Num(coordinator.partition_epoch() as f64),
        );
    }
    json
}

/// The `\x01dump` reply: the entity's indexed addresses on this
/// backend, as `{"tree":…,"node":…}` pairs (empty when not held) — the
/// source side of the rebalancer's `\x01insert` handoff replay.
fn dump_reply(coordinator: &Coordinator, entity: &str) -> Json {
    let addrs = coordinator.dump_entity(entity);
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("entity", Json::Str(entity.to_string())),
        (
            "addresses",
            Json::Arr(
                addrs
                    .iter()
                    .map(|a| {
                        Json::obj(vec![
                            ("tree", Json::Num(a.tree as f64)),
                            ("node", Json::Num(a.node as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// The `\x01repartition` handler: build and install the next epoch's
/// [`KeyPartition`](crate::rag::config::KeyPartition) (`replicas` 0
/// clears the partition — full index — while still advancing the
/// reported epoch, which is how an unpartitioned fleet tracks
/// membership changes).
fn repartition_reply(
    coordinator: &Coordinator,
    epoch: u64,
    replicas: usize,
    index: usize,
    backends: &str,
) -> Json {
    let outcome = if replicas == 0 {
        coordinator.set_partition(None, epoch)
    } else {
        let addrs: Vec<&str> = backends
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .collect();
        crate::rag::config::KeyPartition::new(addrs, index, replicas)
            .and_then(|p| {
                coordinator.set_partition(Some(p.with_epoch(epoch)), epoch)
            })
    };
    match outcome {
        Ok(()) => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("partition_epoch", Json::Num(epoch as f64)),
            ("replicas", Json::Num(replicas as f64)),
        ]),
        Err(e) => Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("error", Json::Str(e.to_string())),
        ]),
    }
}

/// The one-line ack for a dynamic-update control line: `ok` is whether
/// the backend processed the request, `applied` whether the index
/// actually changed (a deleted-but-absent key acks `applied:false`).
fn update_ack(outcome: Result<bool>) -> Json {
    match outcome {
        Ok(applied) => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("applied", Json::Bool(applied)),
        ]),
        Err(e) => Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("applied", Json::Bool(false)),
            ("error", Json::Str(e.to_string())),
        ]),
    }
}

/// Build the JSON reply for one query (exposed for tests).
pub fn respond(coordinator: &Coordinator, query: &str) -> Json {
    match coordinator.query_blocking(query) {
        Ok(r) => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("answer", Json::Str(r.answer)),
            (
                "entities",
                Json::Arr(r.entities.into_iter().map(Json::Str).collect()),
            ),
            ("facts", Json::Num(r.fact_count as f64)),
            (
                "retrieval_us",
                Json::Num(r.retrieval_time.as_micros() as f64),
            ),
            ("total_ms", Json::Num(r.total_time.as_millis() as f64)),
        ]),
        Err(e) => Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("error", Json::Str(e.to_string())),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::CoordinatorConfig;
    use crate::data::corpus::corpus_from_texts;
    use crate::data::hospital::{HospitalConfig, HospitalDataset};
    use crate::rag::config::RagConfig;
    use crate::runtime::engine::{Engine, NativeEngine};
    use std::io::{BufRead, BufReader, Write};

    fn coordinator() -> Arc<Coordinator> {
        let ds = HospitalDataset::generate(HospitalConfig {
            trees: 4,
            ..HospitalConfig::default()
        });
        let forest = Arc::new(ds.build_forest());
        let docs = corpus_from_texts(&ds.documents());
        let engine: Arc<dyn Engine> = Arc::new(NativeEngine::new());
        Arc::new(
            Coordinator::start(
                forest,
                docs,
                engine,
                RagConfig::default(),
                CoordinatorConfig { workers: 2, ..Default::default() },
            )
            .unwrap(),
        )
    }

    #[test]
    fn respond_builds_json() {
        let c = coordinator();
        let json = respond(&c, "describe the hierarchy around cardiology");
        assert_eq!(json.get("ok"), Some(&Json::Bool(true)));
        assert!(json.get("answer").unwrap().as_str().unwrap().len() > 10);
    }

    #[test]
    fn accept_error_does_not_kill_listener() {
        let c = coordinator();
        // a transient accept failure is absorbed (pre-PR-2 this bubbled
        // out of serve() and killed the listener)...
        for kind in [
            std::io::ErrorKind::ConnectionAborted,
            std::io::ErrorKind::Interrupted,
            std::io::ErrorKind::Other, // e.g. EMFILE surfaces as Other/Uncategorized
        ] {
            accept_one(&c, Err(std::io::Error::from(kind)));
        }
        // ...and the very same accept path still serves a real
        // connection afterwards.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut client = TcpStream::connect(addr).unwrap();
            client
                .write_all(b"what is the parent unit of cardiology\n:quit\n")
                .unwrap();
            let mut reader = BufReader::new(client);
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            line
        });
        let (stream, _) = listener.accept().unwrap();
        accept_one(&c, Ok(stream));
        let line = client.join().unwrap();
        assert!(line.contains("\"ok\":true"), "{line}");
    }

    #[test]
    fn stats_control_line_returns_metrics_json() {
        let c = coordinator();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = {
            let c = c.clone();
            std::thread::spawn(move || {
                let (stream, _) = listener.accept().unwrap();
                handle_conn(c, stream).unwrap();
            })
        };
        let mut client = TcpStream::connect(addr).unwrap();
        // one real query, then the stats line: the snapshot must count it
        client
            .write_all(b"what is the parent unit of cardiology\n\x01stats\n:quit\n")
            .unwrap();
        let mut reader = BufReader::new(client.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":true"), "{line}");
        line.clear();
        reader.read_line(&mut line).unwrap();
        let snap = Json::parse(line.trim()).expect("stats reply is JSON");
        assert_eq!(snap.get("requests").and_then(Json::as_f64), Some(1.0));
        assert!(snap.get("total_mean_s").is_some());
        server.join().unwrap();
    }

    #[test]
    fn stopped_coordinator_drops_connections_instead_of_answering() {
        let c = coordinator();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = {
            let c = c.clone();
            std::thread::spawn(move || {
                let (stream, _) = listener.accept().unwrap();
                let _ = handle_conn(c, stream);
            })
        };
        let mut client = TcpStream::connect(addr).unwrap();
        c.stop();
        // even the stats control line must NOT be answered once the
        // coordinator is stopped — the router's prober relies on a dead
        // backend going silent, not serving stale control replies
        client.write_all(b"\x01stats\n").unwrap();
        let mut reader = BufReader::new(client);
        let mut line = String::new();
        let n = reader.read_line(&mut line).unwrap();
        assert_eq!(n, 0, "expected EOF, got {line:?}");
        server.join().unwrap();
    }

    #[test]
    fn serve_with_shutdown_stops_and_releases_port() {
        let c = coordinator();
        let handle = serve_with_shutdown(c, "127.0.0.1:0").unwrap();
        let addr = handle.addr();
        // served while up
        let mut client = TcpStream::connect(addr).unwrap();
        client
            .write_all(b"what is the parent unit of cardiology\n:quit\n")
            .unwrap();
        let mut reader = BufReader::new(client);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":true"), "{line}");
        // stops without hanging, and the port is rebindable — the
        // listener did not leak
        handle.shutdown();
        TcpListener::bind(addr).expect("port released after shutdown");
    }

    #[test]
    fn parse_control_lines() {
        assert_eq!(parse_control("plain query"), None);
        assert_eq!(parse_control("\x01stats"), Some(Ok(ControlLine::Stats)));
        assert_eq!(
            parse_control("\x01insert 3 14 ward 9"),
            Some(Ok(ControlLine::Insert { tree: 3, node: 14, entity: "ward 9" }))
        );
        assert_eq!(
            parse_control("\x01delete intensive care"),
            Some(Ok(ControlLine::Delete { entity: "intensive care" }))
        );
        assert_eq!(
            parse_control("\x01dump ward 9"),
            Some(Ok(ControlLine::Dump { entity: "ward 9" }))
        );
        assert_eq!(
            parse_control("\x01repartition 2 1 0 a:1,b:2"),
            Some(Ok(ControlLine::Repartition {
                epoch: 2,
                replicas: 1,
                index: 0,
                backends: "a:1,b:2",
            }))
        );
        assert_eq!(parse_control("\x01purge"), Some(Ok(ControlLine::Purge)));
        assert_eq!(
            parse_control("\x01join 127.0.0.1:7184"),
            Some(Ok(ControlLine::Join { addr: "127.0.0.1:7184" }))
        );
        assert_eq!(
            parse_control("\x01drain 127.0.0.1:7184"),
            Some(Ok(ControlLine::Drain { addr: "127.0.0.1:7184" }))
        );
        for bad in [
            "\x01stats now",
            "\x01insert",
            "\x01insert x y z",
            "\x01insert 1 2",
            "\x01delete",
            "\x01dump",
            "\x01repartition",
            "\x01repartition 1 2",
            "\x01repartition x 1 0 a:1",
            "\x01repartition 1 1 0",
            "\x01purge now",
            "\x01join",
            "\x01drain",
            "\x01launch missiles",
        ] {
            assert!(
                matches!(parse_control(bad), Some(Err(_))),
                "{bad:?} must be rejected"
            );
        }
    }

    #[test]
    fn rebalance_control_lines_roundtrip_over_tcp() {
        let c = coordinator();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = {
            let c = c.clone();
            std::thread::spawn(move || {
                let (stream, _) = listener.accept().unwrap();
                handle_conn(c, stream).unwrap();
            })
        };
        let mut client = TcpStream::connect(addr).unwrap();
        client
            .write_all(
                b"\x01stats\n\
                  \x01dump cardiology\n\
                  \x01repartition 1 0 0 x:1\n\
                  \x01stats\n\
                  \x01purge\n\
                  \x01join 10.0.0.9:1\n\
                  :quit\n",
            )
            .unwrap();
        let mut reader = BufReader::new(client);
        let mut next = || {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            Json::parse(line.trim()).expect("reply is JSON")
        };
        // fresh backend reports epoch 0 in its stats payload
        let stats = next();
        assert_eq!(
            stats.get("partition_epoch").and_then(Json::as_f64),
            Some(0.0),
            "{stats}"
        );
        // dump returns the entity's address objects
        let dump = next();
        assert_eq!(dump.get("ok"), Some(&Json::Bool(true)), "{dump}");
        let addrs = dump.get("addresses").and_then(Json::as_arr).unwrap();
        assert!(!addrs.is_empty(), "{dump}");
        assert!(addrs[0].get("tree").and_then(Json::as_f64).is_some());
        assert!(addrs[0].get("node").and_then(Json::as_f64).is_some());
        // repartition with replicas=0 keeps the full index but advances
        // the reported epoch
        let rep = next();
        assert_eq!(rep.get("ok"), Some(&Json::Bool(true)), "{rep}");
        assert_eq!(
            rep.get("partition_epoch").and_then(Json::as_f64),
            Some(1.0)
        );
        let stats = next();
        assert_eq!(
            stats.get("partition_epoch").and_then(Json::as_f64),
            Some(1.0),
            "{stats}"
        );
        // purge on a full index drops nothing
        let purge = next();
        assert_eq!(purge.get("ok"), Some(&Json::Bool(true)), "{purge}");
        assert_eq!(purge.get("dropped").and_then(Json::as_f64), Some(0.0));
        // join is a router verb: backends refuse it
        let join = next();
        assert_eq!(join.get("ok"), Some(&Json::Bool(false)), "{join}");
        server.join().unwrap();
    }

    #[test]
    fn update_control_lines_ack_over_tcp() {
        let c = coordinator();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = {
            let c = c.clone();
            std::thread::spawn(move || {
                let (stream, _) = listener.accept().unwrap();
                handle_conn(c, stream).unwrap();
            })
        };
        let mut client = TcpStream::connect(addr).unwrap();
        // delete a known entity, idempotently re-delete, reject garbage
        client
            .write_all(
                b"\x01delete cardiology\n\x01delete cardiology\n\
                  \x01insert 0 99999 cardiology\n:quit\n",
            )
            .unwrap();
        let mut reader = BufReader::new(client);
        let mut expect = |ok: bool, applied: bool| {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let json = Json::parse(line.trim()).expect("ack is JSON");
            assert_eq!(json.get("ok"), Some(&Json::Bool(ok)), "{line}");
            assert_eq!(
                json.get("applied"),
                Some(&Json::Bool(applied)),
                "{line}"
            );
        };
        expect(true, true); // first delete applied
        expect(true, false); // second is an idempotent no-op
        expect(false, false); // out-of-range node rejected
        server.join().unwrap();
    }

    #[test]
    fn tcp_roundtrip() {
        let c = coordinator();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = {
            let c = c.clone();
            std::thread::spawn(move || {
                let (stream, _) = listener.accept().unwrap();
                handle_conn(c, stream).unwrap();
            })
        };
        let mut client = TcpStream::connect(addr).unwrap();
        client
            .write_all(b"what is the parent unit of cardiology\n:quit\n")
            .unwrap();
        let mut reader = BufReader::new(client.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":true"), "{line}");
        server.join().unwrap();
    }
}
