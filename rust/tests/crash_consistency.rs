//! Crash-consistency proof for the durable backend (`persist/`): a
//! REAL `cft-rag serve` subprocess is driven through Zipf insert/delete
//! churn over its TCP protocol, SIGKILLed at a seed-derived point (with
//! or without an op in flight), restarted from the same `--data-dir`,
//! and its recovered index compared against the model of every ACKED
//! write:
//!
//! - **no lost acknowledged writes** — every insert/delete the backend
//!   acked before the kill is present after snapshot + op-log replay
//!   (`--fsync-every 1`: an ack means the log record was fsynced);
//! - **no resurrected deletes** — an acked delete stays deleted even
//!   though the restart rebuilds nothing from the forest;
//! - an op **in flight at the kill** (sent, never acked) may have
//!   landed or not — both outcomes are legal, torn tail records are
//!   truncated silently.
//!
//! Each seed is one schedule (kill point, kill mode, snapshot cadence).
//! Failures print the seed and a one-line replay command, matching the
//! modelcheck convention (`docs/TESTING.md`). Replay one schedule with:
//!
//! ```text
//! CFT_CRASH_SEED=<seed> cargo test -q --test crash_consistency -- --nocapture
//! ```

#![cfg(unix)] // Child::kill = SIGKILL; the whole point is an uncatchable stop

mod support;

use std::collections::{BTreeMap, BTreeSet};

use cft_rag::util::json::Json;
use cft_rag::util::rng::{Rng, Zipf};
use support::{free_port, scratch_dir, BackendProc};

/// ≥ 8 seeded SIGKILL points (ISSUE 9 acceptance): kill points 7..=16,
/// alternating ack-boundary / op-in-flight kills, every third schedule
/// with mid-churn auto-snapshots so recovery = snapshot + log tail.
const SEEDS: [u64; 10] = [1, 2, 3, 4, 5, 6, 7, 8, 9, 10];

const ENTITIES: usize = 40;
const TREES: u32 = 12; // matches the harness's `--trees 12`

#[derive(Clone, Copy, Debug)]
enum Op {
    Insert { entity: usize, tree: u32 },
    Delete { entity: usize },
}

/// The durable-state model: entity → acked address set (node is always
/// 0 — every tree's root exists, so every (tree, 0) is in bounds).
type Model = BTreeMap<usize, BTreeSet<(u32, u32)>>;

fn apply(model: &mut Model, op: Op) {
    match op {
        Op::Insert { entity, tree } => {
            model.entry(entity).or_default().insert((tree, 0));
        }
        Op::Delete { entity } => {
            model.remove(&entity);
        }
    }
}

fn entity_name(i: usize) -> String {
    format!("churn-{i}")
}

fn random_op(rng: &mut Rng, zipf: &Zipf) -> Op {
    let entity = zipf.sample(rng);
    if rng.chance(0.7) {
        Op::Insert { entity, tree: rng.below(TREES as u64) as u32 }
    } else {
        Op::Delete { entity }
    }
}

fn op_line(op: Op) -> String {
    match op {
        Op::Insert { entity, tree } => {
            format!("\x01insert {tree} 0 {}", entity_name(entity))
        }
        Op::Delete { entity } => {
            format!("\x01delete {}", entity_name(entity))
        }
    }
}

/// One seeded schedule: churn → SIGKILL → restart → verify.
fn run_schedule(seed: u64) {
    let kill_point = 6 + (seed % 40) as usize;
    let in_flight_kill = seed % 2 == 1;
    let snapshot_interval = if seed % 3 == 0 { 16 } else { 0 };
    let replay = format!(
        "CFT_CRASH_SEED={seed} cargo test -q --test crash_consistency \
         -- --nocapture"
    );
    eprintln!(
        "crash schedule seed={seed}: kill after {kill_point} acked ops \
         ({}), snapshot interval {snapshot_interval}  [replay: {replay}]",
        if in_flight_kill { "one op in flight" } else { "ack boundary" },
    );

    let dir = scratch_dir(&format!("crash-{seed}"));
    let snapshot_arg = snapshot_interval.to_string();
    let extra: Vec<&str> = if snapshot_interval > 0 {
        vec!["--fsync-every", "1", "--snapshot-interval-ops", &snapshot_arg]
    } else {
        vec!["--fsync-every", "1"]
    };

    // churn: every op below is ACKED before the next is sent
    let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let zipf = Zipf::new(ENTITIES, 1.2);
    let mut model = Model::new();
    let mut backend = BackendProc::spawn(free_port(), &dir, &extra);
    let mut client = backend.client();
    for i in 0..kill_point {
        let op = random_op(&mut rng, &zipf);
        let reply = client.send(&op_line(op));
        assert_eq!(
            reply.get("ok"),
            Some(&Json::Bool(true)),
            "seed {seed}: op {i} {op:?} not acked: {reply}  [{replay}]"
        );
        apply(&mut model, op);
    }
    // optionally leave one op IN FLIGHT (sent, ack never read) so the
    // kill can land mid-record — either outcome must be recoverable
    let pending = in_flight_kill.then(|| {
        let op = random_op(&mut rng, &zipf);
        client.send_no_reply(&op_line(op));
        op
    });
    backend.kill();
    drop(client);

    // restart WARM from the same data dir and compare every entity
    // against the model of acked writes
    let backend = BackendProc::spawn(free_port(), &dir, &extra);
    let mut client = backend.client();
    let mut with_pending = model.clone();
    if let Some(op) = pending {
        apply(&mut with_pending, op);
    }
    for e in 0..ENTITIES {
        let actual: BTreeSet<(u32, u32)> =
            client.dump(&entity_name(e)).into_iter().collect();
        let acked = model.get(&e).cloned().unwrap_or_default();
        let optional = with_pending.get(&e).cloned().unwrap_or_default();
        assert!(
            actual == acked || actual == optional,
            "seed {seed}: entity {:?} diverged after restart —\n  \
             recovered: {actual:?}\n  acked:     {acked:?}\n  \
             acked+in-flight: {optional:?}\n  replay: {replay}",
            entity_name(e)
        );
    }

    // the recovered process is a fully serving backend: durability
    // counters are exported and new writes ack and read back
    let stats = client.stats();
    let durability = stats
        .get("durability")
        .unwrap_or_else(|| panic!("seed {seed}: stats lack durability: {stats}"));
    assert!(
        durability.get("log_replayed").and_then(Json::as_f64).is_some(),
        "seed {seed}: {stats}"
    );
    let reply = client.insert("churn-post-restart", 0, 0);
    assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply}");
    assert_eq!(client.dump("churn-post-restart"), vec![(0, 0)]);

    drop(client);
    drop(backend);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn acked_writes_survive_sigkill_at_every_seeded_point() {
    // CFT_CRASH_SEED replays one failing schedule in isolation
    if let Ok(seed) = std::env::var("CFT_CRASH_SEED") {
        let seed: u64 = seed.parse().expect("CFT_CRASH_SEED must be a u64");
        run_schedule(seed);
        return;
    }
    for seed in SEEDS {
        run_schedule(seed);
    }
}
