//! Word tokenizer + hash-token ids for the L2 embedder artifact.
//!
//! The embed artifact consumes fixed-shape `int32` token-id batches
//! (`[B, MAX_TOKENS]`, PAD_ID = 0). Ids are produced here by hashing
//! words into a bounded vocabulary with the shared FNV-1a hash; the
//! embedder's random-feature construction (see python/compile/model.py)
//! only needs ids to be deterministic and well-spread, not trained.

use crate::text::normalize::normalize;
use crate::text::stopwords::is_stopword;
use crate::util::rng::fnv1a;

/// Padding id — must match `PAD_ID` in python/compile/model.py.
pub const PAD_ID: i32 = 0;

/// Hash vocabulary size. Prime, and small enough that ids stay exactly
/// representable in f32 inside the embedder's `sin(id * freq)` features.
pub const VOCAB: i32 = 32_749;

/// Hash one (lowercased) word to a token id in `[1, VOCAB]`.
pub fn token_id(word: &str) -> i32 {
    (fnv1a(word.as_bytes()) % VOCAB as u64) as i32 + 1
}

/// Tokenize text into hash ids: normalize, split, drop stopwords.
pub fn tokenize(text: &str) -> Vec<i32> {
    normalize(text)
        .split_whitespace()
        .filter(|w| !is_stopword(w))
        .map(token_id)
        .collect()
}

/// Tokenize and pad/truncate to exactly `max_len` ids.
pub fn tokenize_padded(text: &str, max_len: usize) -> Vec<i32> {
    let mut ids = tokenize(text);
    ids.truncate(max_len);
    ids.resize(max_len, PAD_ID);
    ids
}

/// Tokenize keeping the content *words* (for NER/relations, which work on
/// surface forms rather than ids).
pub fn content_words(text: &str) -> Vec<String> {
    normalize(text)
        .split_whitespace()
        .filter(|w| !is_stopword(w))
        .map(str::to_string)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_deterministic_and_in_range() {
        let a = tokenize("Cardiology ward nine");
        let b = tokenize("cardiology ward NINE!");
        assert_eq!(a, b, "normalization-invariant");
        for &id in &a {
            assert!(id >= 1 && id <= VOCAB);
        }
    }

    #[test]
    fn stopwords_dropped() {
        let ids = tokenize("the history of the hospital");
        assert_eq!(ids.len(), 2, "only 'history' and 'hospital' remain");
    }

    #[test]
    fn padded_shape_exact() {
        let ids = tokenize_padded("alpha beta", 8);
        assert_eq!(ids.len(), 8);
        assert_ne!(ids[0], PAD_ID);
        assert_ne!(ids[1], PAD_ID);
        assert!(ids[2..].iter().all(|&i| i == PAD_ID));
    }

    #[test]
    fn padded_truncates() {
        let long: String = (0..50).map(|i| format!("word{i} ")).collect();
        let ids = tokenize_padded(&long, 8);
        assert_eq!(ids.len(), 8);
        assert!(ids.iter().all(|&i| i != PAD_ID));
    }

    #[test]
    fn distinct_words_rarely_collide() {
        let ids: Vec<i32> = (0..500)
            .map(|i| token_id(&format!("entity-{i}")))
            .collect();
        let mut uniq = ids.clone();
        uniq.sort_unstable();
        uniq.dedup();
        // FNV over 32k vocab: expect only a handful of collisions in 500
        assert!(uniq.len() >= 490, "{} unique of 500", uniq.len());
    }

    #[test]
    fn content_words_surface_forms() {
        let ws = content_words("The Cardiology Department of Mercy Hospital");
        assert_eq!(ws, vec!["cardiology", "mercy", "hospital"]);
    }
}
