"""L2 model graph tests: shapes, determinism, retrieval semantics."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model


def _tok(rng, b):
    """Random padded token batch."""
    lens = rng.integers(1, model.MAX_TOKENS + 1, size=b)
    out = np.zeros((b, model.MAX_TOKENS), np.int32)
    for i, ln in enumerate(lens):
        out[i, :ln] = rng.integers(1, 50_000, size=ln)
    return jnp.asarray(out)


def test_embed_shape_and_norm():
    rng = np.random.default_rng(0)
    tokens = _tok(rng, model.BATCH)
    e = np.asarray(model.embed(tokens))
    assert e.shape == (model.BATCH, model.EMBED_DIM)
    np.testing.assert_allclose(
        np.linalg.norm(e, axis=1), np.ones(model.BATCH), rtol=1e-5
    )


def test_embed_deterministic():
    rng = np.random.default_rng(1)
    tokens = _tok(rng, 4)
    a = np.asarray(model.embed(tokens))
    b = np.asarray(model.embed(tokens))
    np.testing.assert_array_equal(a, b)


def test_embed_token_order_invariant_up_to_count():
    """Mean pooling => same multiset of tokens embeds identically."""
    ids = np.zeros((2, model.MAX_TOKENS), np.int32)
    ids[0, :3] = [7, 11, 13]
    ids[1, :3] = [13, 7, 11]
    e = np.asarray(model.embed(jnp.asarray(ids)))
    np.testing.assert_allclose(e[0], e[1], rtol=1e-5, atol=1e-6)


def test_embed_similarity_tracks_token_overlap():
    """More shared tokens => higher cosine similarity."""
    base = [5, 9, 21, 33, 47, 60]
    rows = np.zeros((3, model.MAX_TOKENS), np.int32)
    rows[0, :6] = base
    rows[1, :6] = base[:4] + [900, 901]        # 4/6 overlap
    rows[2, :6] = [700, 701, 702, 703, 704, 705]  # disjoint
    e = np.asarray(model.embed(jnp.asarray(rows)))
    sim_close = float(e[0] @ e[1])
    sim_far = float(e[0] @ e[2])
    assert sim_close > sim_far + 0.2


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_score_top1_is_self(seed):
    """A doc queried against a shard containing it ranks itself first."""
    rng = np.random.default_rng(seed)
    docs = rng.standard_normal((model.SHARD_DOCS, model.EMBED_DIM)).astype(
        np.float32
    )
    docs /= np.linalg.norm(docs, axis=1, keepdims=True)
    idx = rng.integers(0, model.SHARD_DOCS, size=model.BATCH)
    q = docs[idx]
    s = np.asarray(model.score(jnp.asarray(q), jnp.asarray(docs)))
    assert s.shape == (model.BATCH, model.SHARD_DOCS)
    np.testing.assert_array_equal(s.argmax(axis=1), idx)


def test_rank_shapes_and_mask():
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.standard_normal((model.BATCH, model.EMBED_DIM)), jnp.float32)
    facts = jnp.asarray(
        rng.standard_normal((model.BATCH, model.MAX_FACTS, model.EMBED_DIM)),
        jnp.float32,
    )
    lens = jnp.asarray([0, 1, 5, 64, 10, 2, 7, 33], jnp.int32)
    w = np.asarray(model.rank(q, facts, lens))
    assert w.shape == (model.BATCH, model.MAX_FACTS)
    for i, ln in enumerate([0, 1, 5, 64, 10, 2, 7, 33]):
        assert (w[i, ln:] == 0).all()
        if ln:
            np.testing.assert_allclose(w[i].sum(), 1.0, rtol=1e-5)
