//! Tree traversal: BFS iteration (the Naive T-RAG search primitive) and
//! the n-level ancestor/descendant walks used by context generation
//! (paper Algorithm 3's `H_up` / `H_down`).

use std::collections::VecDeque;

use crate::forest::address::EntityAddress;
use crate::forest::forest::Forest;
use crate::forest::interner::EntityId;
use crate::forest::tree::{NodeIdx, Tree};

/// Breadth-first iterator over a tree's node indices.
pub struct Bfs<'a> {
    tree: &'a Tree,
    queue: VecDeque<NodeIdx>,
}

impl<'a> Bfs<'a> {
    /// BFS from the root.
    pub fn new(tree: &'a Tree) -> Self {
        let mut queue = VecDeque::new();
        if !tree.is_empty() {
            queue.push_back(tree.root());
        }
        Bfs { tree, queue }
    }
}

impl<'a> Iterator for Bfs<'a> {
    type Item = NodeIdx;

    fn next(&mut self) -> Option<NodeIdx> {
        let idx = self.queue.pop_front()?;
        for &c in &self.tree.node(idx).children {
            self.queue.push_back(c);
        }
        Some(idx)
    }
}

/// Up to `n` ancestors of `addr`, nearest first (parent, grandparent, ...).
pub fn ancestors(forest: &Forest, addr: EntityAddress, n: usize) -> Vec<EntityId> {
    let tree = forest.tree(addr.tree);
    let mut out = Vec::new();
    let mut cur = tree.node(addr.node).parent;
    while let Some(p) = cur {
        if out.len() >= n {
            break;
        }
        out.push(tree.entity(p));
        cur = tree.node(p).parent;
    }
    out
}

/// Descendants of `addr` down to `n` levels, BFS order (children first).
pub fn descendants(forest: &Forest, addr: EntityAddress, n: usize) -> Vec<EntityId> {
    descendants_with_depth(forest, addr, n)
        .into_iter()
        .map(|(e, _)| e)
        .collect()
}

/// Like [`descendants`], also returning each node's distance below `addr`
/// (1 = direct child).
pub fn descendants_with_depth(
    forest: &Forest,
    addr: EntityAddress,
    n: usize,
) -> Vec<(EntityId, u32)> {
    let tree = forest.tree(addr.tree);
    let base_depth = tree.node(addr.node).depth;
    let mut out = Vec::new();
    let mut queue = VecDeque::new();
    queue.push_back(addr.node);
    while let Some(idx) = queue.pop_front() {
        for &c in &tree.node(idx).children {
            let d = tree.node(c).depth - base_depth;
            if d as usize <= n {
                out.push((tree.entity(c), d));
                queue.push_back(c);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::forest::tree::Tree;

    /// hospital -> {cardiology -> {icu, ward}, surgery -> {theatre}}
    fn forest() -> Forest {
        let mut f = Forest::new();
        let ids: Vec<EntityId> = ["hospital", "cardiology", "surgery", "icu", "ward", "theatre"]
            .iter()
            .map(|n| f.intern(n))
            .collect();
        let mut t = Tree::with_root(ids[0]);
        let card = t.add_child(0, ids[1]);
        let surg = t.add_child(0, ids[2]);
        t.add_child(card, ids[3]);
        t.add_child(card, ids[4]);
        t.add_child(surg, ids[5]);
        f.add_tree(t);
        f
    }

    #[test]
    fn bfs_visits_level_order() {
        let f = forest();
        let t = f.tree(0);
        let order: Vec<&str> = Bfs::new(t)
            .map(|i| f.entity_name(t.entity(i)))
            .collect();
        assert_eq!(order, vec!["hospital", "cardiology", "surgery", "icu", "ward", "theatre"]);
    }

    #[test]
    fn ancestors_nearest_first() {
        let f = forest();
        let icu = f.entity_id("icu").unwrap();
        let addr = f.scan_addresses(icu)[0];
        let up: Vec<&str> = ancestors(&f, addr, 5)
            .iter()
            .map(|&e| f.entity_name(e))
            .collect();
        assert_eq!(up, vec!["cardiology", "hospital"]);
    }

    #[test]
    fn ancestors_respects_n() {
        let f = forest();
        let icu = f.entity_id("icu").unwrap();
        let addr = f.scan_addresses(icu)[0];
        assert_eq!(ancestors(&f, addr, 1).len(), 1);
        assert_eq!(ancestors(&f, addr, 0).len(), 0);
    }

    #[test]
    fn descendants_bfs_and_depth_limited() {
        let f = forest();
        let hosp = f.entity_id("hospital").unwrap();
        let addr = f.scan_addresses(hosp)[0];
        let one: Vec<&str> = descendants(&f, addr, 1)
            .iter()
            .map(|&e| f.entity_name(e))
            .collect();
        assert_eq!(one, vec!["cardiology", "surgery"]);
        let two = descendants(&f, addr, 2);
        assert_eq!(two.len(), 5);
    }

    #[test]
    fn descendants_of_leaf_empty() {
        let f = forest();
        let icu = f.entity_id("icu").unwrap();
        let addr = f.scan_addresses(icu)[0];
        assert!(descendants(&f, addr, 3).is_empty());
    }
}
