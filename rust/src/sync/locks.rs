//! Model-checkable `Mutex` and `RwLock` (`--features modelcheck`).
//!
//! Each lock pairs the real std primitive (which still owns the data
//! and the poisoning semantics) with a *logical ownership book* the
//! scheduler consults. On a model vthread, acquisition is decided
//! against the book under the scheduler's control — contenders park as
//! virtual threads and the schedule explores who wins — and only then
//! is the inner std lock taken, which is guaranteed uncontended at
//! that point (exactly one vthread runs at a time and the book grants
//! exclusivity). Off-model threads skip the book entirely and behave
//! like plain std locks.

use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::{LockResult, PoisonError, TryLockError, TryLockResult};

use crate::modelcheck::managed;

/// Logical ownership record, keyed by vthread id.
#[derive(Default)]
struct Book {
    writer: Option<usize>,
    readers: usize,
}

/// Error for a lock reached by both model vthreads and ordinary
/// threads at once — outside the supported usage (see `sync` docs).
const MIXED_USE: &str =
    "modelcheck lock: inner std lock held outside the model \
     (a primitive is shared between model vthreads and ordinary threads)";

fn book_of(m: &std::sync::Mutex<Book>) -> std::sync::MutexGuard<'_, Book> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

// ---------------------------------------------------------------- Mutex

/// Drop-in `std::sync::Mutex` that the model scheduler can preempt
/// around and reason about (deadlock detection, schedule exploration).
pub struct Mutex<T: ?Sized> {
    book: std::sync::Mutex<Book>,
    inner: std::sync::Mutex<T>,
}

impl<T> Mutex<T> {
    /// See [`std::sync::Mutex::new`].
    pub fn new(value: T) -> Self {
        Mutex {
            book: std::sync::Mutex::new(Book::default()),
            inner: std::sync::Mutex::new(value),
        }
    }

    /// See [`std::sync::Mutex::into_inner`].
    pub fn into_inner(self) -> LockResult<T> {
        self.inner.into_inner()
    }
}

impl<T: ?Sized> Mutex<T> {
    /// Scheduler resource id: the book's address (stable for the
    /// lock's lifetime, never collides with the small built-in ids).
    fn res(&self) -> usize {
        &self.book as *const std::sync::Mutex<Book> as usize
    }

    /// See [`std::sync::Mutex::lock`]. Under a model run this is a
    /// scheduling point and may park the vthread.
    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        if let Some((sh, vtid)) = managed() {
            loop {
                sh.yield_point(vtid);
                {
                    let mut b = book_of(&self.book);
                    if b.writer.is_none() && b.readers == 0 {
                        b.writer = Some(vtid);
                        break;
                    }
                }
                sh.block(vtid, self.res(), "mutex", None);
            }
            match self.inner.try_lock() {
                Ok(g) => Ok(MutexGuard { lock: self, inner: Some(g), managed: true }),
                Err(TryLockError::Poisoned(p)) => Err(PoisonError::new(
                    MutexGuard { lock: self, inner: Some(p.into_inner()), managed: true },
                )),
                Err(TryLockError::WouldBlock) => panic!("{MIXED_USE}"),
            }
        } else {
            match self.inner.lock() {
                Ok(g) => Ok(MutexGuard { lock: self, inner: Some(g), managed: false }),
                Err(p) => Err(PoisonError::new(MutexGuard {
                    lock: self,
                    inner: Some(p.into_inner()),
                    managed: false,
                })),
            }
        }
    }

    /// See [`std::sync::Mutex::try_lock`].
    pub fn try_lock(&self) -> TryLockResult<MutexGuard<'_, T>> {
        if let Some((sh, vtid)) = managed() {
            sh.yield_point(vtid);
            {
                let mut b = book_of(&self.book);
                if b.writer.is_some() || b.readers > 0 {
                    return Err(TryLockError::WouldBlock);
                }
                b.writer = Some(vtid);
            }
            match self.inner.try_lock() {
                Ok(g) => Ok(MutexGuard { lock: self, inner: Some(g), managed: true }),
                Err(TryLockError::Poisoned(p)) => {
                    Err(TryLockError::Poisoned(PoisonError::new(MutexGuard {
                        lock: self,
                        inner: Some(p.into_inner()),
                        managed: true,
                    })))
                }
                Err(TryLockError::WouldBlock) => panic!("{MIXED_USE}"),
            }
        } else {
            match self.inner.try_lock() {
                Ok(g) => Ok(MutexGuard { lock: self, inner: Some(g), managed: false }),
                Err(TryLockError::Poisoned(p)) => {
                    Err(TryLockError::Poisoned(PoisonError::new(MutexGuard {
                        lock: self,
                        inner: Some(p.into_inner()),
                        managed: false,
                    })))
                }
                Err(TryLockError::WouldBlock) => Err(TryLockError::WouldBlock),
            }
        }
    }

    /// See [`std::sync::Mutex::get_mut`].
    pub fn get_mut(&mut self) -> LockResult<&mut T> {
        self.inner.get_mut()
    }
}

impl<T: ?Sized> fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Mutex").finish_non_exhaustive()
    }
}

impl<T: Default> Default for Mutex<T> {
    fn default() -> Self {
        Mutex::new(T::default())
    }
}

/// Guard for the shim [`Mutex`]; releases the logical claim (and wakes
/// parked contenders) on drop.
pub struct MutexGuard<'a, T: ?Sized> {
    lock: &'a Mutex<T>,
    inner: Option<std::sync::MutexGuard<'a, T>>,
    managed: bool,
}

impl<T: ?Sized> Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard taken")
    }
}

impl<T: ?Sized> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard taken")
    }
}

impl<T: ?Sized + fmt::Debug> fmt::Debug for MutexGuard<'_, T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&**self, f)
    }
}

impl<T: ?Sized> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        // Release the std lock before the logical claim so the next
        // logical owner always finds the inner lock free.
        drop(self.inner.take());
        if self.managed {
            book_of(&self.lock.book).writer = None;
            if let Some((sh, _)) = managed() {
                sh.wake(self.lock.res());
            }
        }
    }
}

// --------------------------------------------------------------- RwLock

/// Drop-in `std::sync::RwLock` under scheduler control; see [`Mutex`].
pub struct RwLock<T: ?Sized> {
    book: std::sync::Mutex<Book>,
    inner: std::sync::RwLock<T>,
}

impl<T> RwLock<T> {
    /// See [`std::sync::RwLock::new`].
    pub fn new(value: T) -> Self {
        RwLock {
            book: std::sync::Mutex::new(Book::default()),
            inner: std::sync::RwLock::new(value),
        }
    }

    /// See [`std::sync::RwLock::into_inner`].
    pub fn into_inner(self) -> LockResult<T> {
        self.inner.into_inner()
    }
}

impl<T: ?Sized> RwLock<T> {
    fn res(&self) -> usize {
        &self.book as *const std::sync::Mutex<Book> as usize
    }

    /// See [`std::sync::RwLock::read`]. A scheduling point under a
    /// model run.
    pub fn read(&self) -> LockResult<RwLockReadGuard<'_, T>> {
        if let Some((sh, vtid)) = managed() {
            loop {
                sh.yield_point(vtid);
                {
                    let mut b = book_of(&self.book);
                    if b.writer.is_none() {
                        b.readers += 1;
                        break;
                    }
                }
                sh.block(vtid, self.res(), "rwlock-read", None);
            }
            match self.inner.try_read() {
                Ok(g) => {
                    Ok(RwLockReadGuard { lock: self, inner: Some(g), managed: true })
                }
                Err(TryLockError::Poisoned(p)) => {
                    Err(PoisonError::new(RwLockReadGuard {
                        lock: self,
                        inner: Some(p.into_inner()),
                        managed: true,
                    }))
                }
                Err(TryLockError::WouldBlock) => panic!("{MIXED_USE}"),
            }
        } else {
            match self.inner.read() {
                Ok(g) => {
                    Ok(RwLockReadGuard { lock: self, inner: Some(g), managed: false })
                }
                Err(p) => Err(PoisonError::new(RwLockReadGuard {
                    lock: self,
                    inner: Some(p.into_inner()),
                    managed: false,
                })),
            }
        }
    }

    /// See [`std::sync::RwLock::write`]. A scheduling point under a
    /// model run.
    pub fn write(&self) -> LockResult<RwLockWriteGuard<'_, T>> {
        if let Some((sh, vtid)) = managed() {
            loop {
                sh.yield_point(vtid);
                {
                    let mut b = book_of(&self.book);
                    if b.writer.is_none() && b.readers == 0 {
                        b.writer = Some(vtid);
                        break;
                    }
                }
                sh.block(vtid, self.res(), "rwlock-write", None);
            }
            match self.inner.try_write() {
                Ok(g) => {
                    Ok(RwLockWriteGuard { lock: self, inner: Some(g), managed: true })
                }
                Err(TryLockError::Poisoned(p)) => {
                    Err(PoisonError::new(RwLockWriteGuard {
                        lock: self,
                        inner: Some(p.into_inner()),
                        managed: true,
                    }))
                }
                Err(TryLockError::WouldBlock) => panic!("{MIXED_USE}"),
            }
        } else {
            match self.inner.write() {
                Ok(g) => {
                    Ok(RwLockWriteGuard { lock: self, inner: Some(g), managed: false })
                }
                Err(p) => Err(PoisonError::new(RwLockWriteGuard {
                    lock: self,
                    inner: Some(p.into_inner()),
                    managed: false,
                })),
            }
        }
    }

    /// See [`std::sync::RwLock::try_read`].
    pub fn try_read(&self) -> TryLockResult<RwLockReadGuard<'_, T>> {
        if let Some((sh, vtid)) = managed() {
            sh.yield_point(vtid);
            {
                let mut b = book_of(&self.book);
                if b.writer.is_some() {
                    return Err(TryLockError::WouldBlock);
                }
                b.readers += 1;
            }
            match self.inner.try_read() {
                Ok(g) => {
                    Ok(RwLockReadGuard { lock: self, inner: Some(g), managed: true })
                }
                Err(TryLockError::Poisoned(p)) => {
                    Err(TryLockError::Poisoned(PoisonError::new(RwLockReadGuard {
                        lock: self,
                        inner: Some(p.into_inner()),
                        managed: true,
                    })))
                }
                Err(TryLockError::WouldBlock) => panic!("{MIXED_USE}"),
            }
        } else {
            match self.inner.try_read() {
                Ok(g) => {
                    Ok(RwLockReadGuard { lock: self, inner: Some(g), managed: false })
                }
                Err(TryLockError::Poisoned(p)) => {
                    Err(TryLockError::Poisoned(PoisonError::new(RwLockReadGuard {
                        lock: self,
                        inner: Some(p.into_inner()),
                        managed: false,
                    })))
                }
                Err(TryLockError::WouldBlock) => Err(TryLockError::WouldBlock),
            }
        }
    }

    /// See [`std::sync::RwLock::try_write`] (the sharded filter's
    /// opportunistic migration help relies on this).
    pub fn try_write(&self) -> TryLockResult<RwLockWriteGuard<'_, T>> {
        if let Some((sh, vtid)) = managed() {
            sh.yield_point(vtid);
            {
                let mut b = book_of(&self.book);
                if b.writer.is_some() || b.readers > 0 {
                    return Err(TryLockError::WouldBlock);
                }
                b.writer = Some(vtid);
            }
            match self.inner.try_write() {
                Ok(g) => {
                    Ok(RwLockWriteGuard { lock: self, inner: Some(g), managed: true })
                }
                Err(TryLockError::Poisoned(p)) => {
                    Err(TryLockError::Poisoned(PoisonError::new(RwLockWriteGuard {
                        lock: self,
                        inner: Some(p.into_inner()),
                        managed: true,
                    })))
                }
                Err(TryLockError::WouldBlock) => panic!("{MIXED_USE}"),
            }
        } else {
            match self.inner.try_write() {
                Ok(g) => {
                    Ok(RwLockWriteGuard { lock: self, inner: Some(g), managed: false })
                }
                Err(TryLockError::Poisoned(p)) => {
                    Err(TryLockError::Poisoned(PoisonError::new(RwLockWriteGuard {
                        lock: self,
                        inner: Some(p.into_inner()),
                        managed: false,
                    })))
                }
                Err(TryLockError::WouldBlock) => Err(TryLockError::WouldBlock),
            }
        }
    }

    /// See [`std::sync::RwLock::get_mut`].
    pub fn get_mut(&mut self) -> LockResult<&mut T> {
        self.inner.get_mut()
    }
}

impl<T: ?Sized> fmt::Debug for RwLock<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("RwLock").finish_non_exhaustive()
    }
}

impl<T: Default> Default for RwLock<T> {
    fn default() -> Self {
        RwLock::new(T::default())
    }
}

/// Shared-access guard for the shim [`RwLock`].
pub struct RwLockReadGuard<'a, T: ?Sized> {
    lock: &'a RwLock<T>,
    inner: Option<std::sync::RwLockReadGuard<'a, T>>,
    managed: bool,
}

impl<T: ?Sized> Deref for RwLockReadGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard taken")
    }
}

impl<T: ?Sized + fmt::Debug> fmt::Debug for RwLockReadGuard<'_, T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&**self, f)
    }
}

impl<T: ?Sized> Drop for RwLockReadGuard<'_, T> {
    fn drop(&mut self) {
        drop(self.inner.take());
        if self.managed {
            {
                let mut b = book_of(&self.lock.book);
                b.readers = b.readers.saturating_sub(1);
            }
            if let Some((sh, _)) = managed() {
                sh.wake(self.lock.res());
            }
        }
    }
}

/// Exclusive-access guard for the shim [`RwLock`].
pub struct RwLockWriteGuard<'a, T: ?Sized> {
    lock: &'a RwLock<T>,
    inner: Option<std::sync::RwLockWriteGuard<'a, T>>,
    managed: bool,
}

impl<T: ?Sized> Deref for RwLockWriteGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard taken")
    }
}

impl<T: ?Sized> DerefMut for RwLockWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard taken")
    }
}

impl<T: ?Sized + fmt::Debug> fmt::Debug for RwLockWriteGuard<'_, T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&**self, f)
    }
}

impl<T: ?Sized> Drop for RwLockWriteGuard<'_, T> {
    fn drop(&mut self) {
        drop(self.inner.take());
        if self.managed {
            book_of(&self.lock.book).writer = None;
            if let Some((sh, _)) = managed() {
                sh.wake(self.lock.res());
            }
        }
    }
}
