//! Entity addresses: the (tree, node) coordinates stored in the Cuckoo
//! Filter's block linked lists (paper §3.1). Compact and `Copy` — eight
//! bytes — because the CF stores *every* occurrence of every entity.

/// Position of one entity occurrence in the forest.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct EntityAddress {
    /// Index of the tree within the forest.
    pub tree: u32,
    /// Index of the node within that tree's arena.
    pub node: u32,
}

impl EntityAddress {
    /// Construct an address.
    pub fn new(tree: u32, node: u32) -> Self {
        EntityAddress { tree, node }
    }

    /// Pack into a u64 (tree in high bits) — used for dedup sets.
    pub fn pack(self) -> u64 {
        ((self.tree as u64) << 32) | self.node as u64
    }

    /// Unpack from `pack()` form.
    pub fn unpack(v: u64) -> Self {
        EntityAddress { tree: (v >> 32) as u32, node: v as u32 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_roundtrip() {
        let a = EntityAddress::new(600, 12345);
        assert_eq!(EntityAddress::unpack(a.pack()), a);
    }

    #[test]
    fn ordering_by_tree_then_node() {
        let a = EntityAddress::new(1, 9);
        let b = EntityAddress::new(2, 0);
        assert!(a < b);
    }
}
