//! Bloom filter — the baseline probabilistic membership structure
//! (Bloom 1970), used by the BF / BF2 T-RAG baselines (paper §4.1).
//!
//! Standard bit-array + k hash functions via double hashing
//! (h_i(x) = h1(x) + i·h2(x)), sized from the target false-positive rate:
//! m = -n·ln(p)/ln(2)², k = (m/n)·ln(2).

/// A fixed-size Bloom filter over 64-bit keys.
#[derive(Clone, Debug)]
pub struct BloomFilter {
    bits: Vec<u64>,
    nbits: usize,
    k: u32,
    items: usize,
}

impl BloomFilter {
    /// Sized for `expected_items` at `fp_rate` (clamped to sane bounds).
    pub fn new(expected_items: usize, fp_rate: f64) -> Self {
        let n = expected_items.max(1) as f64;
        let p = fp_rate.clamp(1e-9, 0.5);
        let m = (-n * p.ln() / (2f64.ln() * 2f64.ln())).ceil().max(8.0) as usize;
        let k = ((m as f64 / n) * 2f64.ln()).round().clamp(1.0, 16.0) as u32;
        BloomFilter {
            bits: vec![0; m.div_ceil(64)],
            nbits: m,
            k,
            items: 0,
        }
    }

    #[inline]
    fn positions(&self, key: u64) -> impl Iterator<Item = usize> + '_ {
        // double hashing: two independent mixes of the key
        let h1 = key.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let h2 = (key ^ 0xDEAD_BEEF_CAFE_F00D).wrapping_mul(0xC2B2_AE3D_27D4_EB4F) | 1;
        let nbits = self.nbits as u64;
        (0..self.k as u64).map(move |i| {
            (h1.wrapping_add(i.wrapping_mul(h2)) % nbits) as usize
        })
    }

    /// Insert a key.
    pub fn insert(&mut self, key: u64) {
        let positions: Vec<usize> = self.positions(key).collect();
        for p in positions {
            self.bits[p / 64] |= 1u64 << (p % 64);
        }
        self.items += 1;
    }

    /// Might the key be present? (false => definitely absent)
    pub fn contains(&self, key: u64) -> bool {
        self.positions(key)
            .all(|p| self.bits[p / 64] & (1u64 << (p % 64)) != 0)
    }

    /// Union another filter into this one (must be identically sized).
    pub fn union(&mut self, other: &BloomFilter) {
        assert_eq!(self.nbits, other.nbits, "union of mismatched blooms");
        assert_eq!(self.k, other.k);
        for (a, b) in self.bits.iter_mut().zip(&other.bits) {
            *a |= b;
        }
        self.items += other.items;
    }

    /// Number of hash functions.
    pub fn hashes(&self) -> u32 {
        self.k
    }

    /// Bit-array size.
    pub fn nbits(&self) -> usize {
        self.nbits
    }

    /// Heap bytes.
    pub fn memory_bytes(&self) -> usize {
        self.bits.capacity() * 8
    }

    /// Items inserted (including unions).
    pub fn items(&self) -> usize {
        self.items
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::fnv1a;

    fn key(i: u64) -> u64 {
        fnv1a(&i.to_le_bytes())
    }

    #[test]
    fn no_false_negatives() {
        let mut bf = BloomFilter::new(1000, 0.01);
        for i in 0..1000 {
            bf.insert(key(i));
        }
        for i in 0..1000 {
            assert!(bf.contains(key(i)), "false negative {i}");
        }
    }

    #[test]
    // 110k hash probes: too slow under Miri
    #[cfg_attr(miri, ignore)]
    fn false_positive_rate_near_target() {
        let mut bf = BloomFilter::new(10_000, 0.01);
        for i in 0..10_000 {
            bf.insert(key(i));
        }
        let fps = (100_000..200_000).filter(|&i| bf.contains(key(i))).count();
        let rate = fps as f64 / 100_000.0;
        assert!(rate < 0.03, "fp rate {rate} far above 1% target");
        assert!(rate > 0.001, "fp rate {rate} suspiciously low");
    }

    #[test]
    fn empty_filter_contains_nothing() {
        let bf = BloomFilter::new(100, 0.01);
        assert!((0..1000).all(|i| !bf.contains(key(i))));
    }

    #[test]
    fn union_covers_both_sets() {
        let mut a = BloomFilter::new(1000, 0.01);
        let mut b = BloomFilter::new(1000, 0.01);
        for i in 0..100 {
            a.insert(key(i));
        }
        for i in 100..200 {
            b.insert(key(i));
        }
        a.union(&b);
        for i in 0..200 {
            assert!(a.contains(key(i)));
        }
    }

    #[test]
    fn sizing_scales_with_items() {
        let small = BloomFilter::new(10, 0.01);
        let big = BloomFilter::new(10_000, 0.01);
        assert!(big.nbits() > small.nbits() * 100);
        assert!(small.hashes() >= 1);
    }

    #[test]
    #[should_panic(expected = "mismatched")]
    fn union_size_mismatch_panics() {
        let mut a = BloomFilter::new(10, 0.01);
        let b = BloomFilter::new(10_000, 0.01);
        a.union(&b);
    }
}
