//! Serving metrics: request counters, stage latency histograms, batch
//! fill statistics. Shared across threads behind one mutex (updates are
//! a few hundred ns; contention is negligible at this testbed's rates).

use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::json::Json;
use crate::util::stats::LatencyHistogram;

/// Snapshot of the counters at one instant.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub failures: u64,
    pub batches: u64,
    pub mean_batch_fill: f64,
    pub total_mean_s: f64,
    pub total_p50_s: f64,
    pub total_p99_s: f64,
    pub retrieval_mean_s: f64,
    pub retrieval_p99_s: f64,
}

impl MetricsSnapshot {
    /// Requests per second given an elapsed window.
    pub fn throughput(&self, elapsed: Duration) -> f64 {
        if elapsed.as_secs_f64() == 0.0 {
            0.0
        } else {
            self.requests as f64 / elapsed.as_secs_f64()
        }
    }

    /// JSON form — the payload of the TCP protocol's `\x01stats`
    /// control line (`coordinator/tcp.rs`), which the shard router's
    /// health prober reads to see backend *load*, not just liveness.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::Num(self.requests as f64)),
            ("failures", Json::Num(self.failures as f64)),
            ("batches", Json::Num(self.batches as f64)),
            ("mean_batch_fill", Json::Num(self.mean_batch_fill)),
            ("total_mean_s", Json::Num(self.total_mean_s)),
            ("total_p50_s", Json::Num(self.total_p50_s)),
            ("total_p99_s", Json::Num(self.total_p99_s)),
            ("retrieval_mean_s", Json::Num(self.retrieval_mean_s)),
            ("retrieval_p99_s", Json::Num(self.retrieval_p99_s)),
        ])
    }
}

#[derive(Debug, Default)]
struct Inner {
    requests: u64,
    failures: u64,
    batches: u64,
    batch_fill_sum: u64,
    total: LatencyHistogram,
    retrieval: LatencyHistogram,
}

/// Thread-shared metrics sink.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    inner: Arc<Mutex<Inner>>,
}

impl Metrics {
    /// New empty metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one completed request.
    pub fn record_request(&self, total: Duration, retrieval: Duration) {
        let mut m = self.inner.lock().unwrap();
        m.requests += 1;
        m.total.record(total.as_secs_f64());
        m.retrieval.record(retrieval.as_secs_f64());
    }

    /// Record one failed request.
    pub fn record_failure(&self) {
        self.inner.lock().unwrap().failures += 1;
    }

    /// Record one dispatched batch of `fill` requests.
    pub fn record_batch(&self, fill: usize) {
        let mut m = self.inner.lock().unwrap();
        m.batches += 1;
        m.batch_fill_sum += fill as u64;
    }

    /// Current snapshot.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        MetricsSnapshot {
            requests: m.requests,
            failures: m.failures,
            batches: m.batches,
            mean_batch_fill: if m.batches == 0 {
                0.0
            } else {
                m.batch_fill_sum as f64 / m.batches as f64
            },
            total_mean_s: m.total.mean(),
            total_p50_s: m.total.quantile(0.5),
            total_p99_s: m.total.quantile(0.99),
            retrieval_mean_s: m.retrieval.mean(),
            retrieval_p99_s: m.retrieval.quantile(0.99),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_request(Duration::from_millis(10), Duration::from_micros(50));
        m.record_request(Duration::from_millis(20), Duration::from_micros(70));
        m.record_batch(8);
        m.record_batch(4);
        m.record_failure();
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.failures, 1);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch_fill - 6.0).abs() < 1e-12);
        assert!(s.total_mean_s > 0.009 && s.total_mean_s < 0.021);
        assert!(s.retrieval_mean_s > 0.0);
    }

    #[test]
    fn throughput_math() {
        let m = Metrics::new();
        for _ in 0..100 {
            m.record_request(Duration::from_millis(1), Duration::from_micros(1));
        }
        let s = m.snapshot();
        assert!((s.throughput(Duration::from_secs(10)) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn snapshot_json_roundtrips() {
        let m = Metrics::new();
        m.record_request(Duration::from_millis(10), Duration::from_micros(50));
        m.record_failure();
        let json = m.snapshot().to_json();
        let back = Json::parse(&json.to_string()).unwrap();
        assert_eq!(back.get("requests").and_then(Json::as_f64), Some(1.0));
        assert_eq!(back.get("failures").and_then(Json::as_f64), Some(1.0));
        assert!(back.get("total_mean_s").and_then(Json::as_f64).unwrap() > 0.0);
    }

    #[test]
    fn shared_across_clones() {
        let m = Metrics::new();
        let m2 = m.clone();
        m2.record_request(Duration::from_millis(1), Duration::from_micros(1));
        assert_eq!(m.snapshot().requests, 1);
    }
}
