//! L3 serving coordinator: dynamic batcher, worker pool, metrics, and a
//! TCP front end. See `server.rs` for the stage diagram.

pub mod batcher;
pub mod metrics;
pub mod server;
pub mod tcp;

pub use batcher::{collect_batch, BatchOutcome, BatchPolicy};
pub use metrics::{Metrics, MetricsSnapshot};
pub use server::{Coordinator, CoordinatorConfig, ServeResponse};
