//! Shared name vocabulary for the synthetic dataset generators.
//!
//! Both generators compose entity names from realistic stems so that the
//! tokenizer, NER and relation extractor all see hospital-/org-chart-like
//! surface forms rather than `entity-17`.

/// Hospital department stems (shared across hospitals — the cross-tree
/// entity sharing that makes CF block lists matter).
pub const DEPARTMENTS: &[&str] = &[
    "cardiology", "oncology", "neurology", "radiology", "pediatrics",
    "surgery", "orthopedics", "dermatology", "pathology", "pharmacy",
    "urology", "nephrology", "hematology", "psychiatry", "gastroenterology",
    "ophthalmology", "anesthesiology", "rheumatology", "endocrinology",
    "pulmonology", "geriatrics", "obstetrics", "immunology", "neonatology",
    "toxicology", "virology", "audiology", "neurosurgery", "traumatology",
    "physiotherapy",
];

/// Sub-unit stems hung below departments.
pub const SUBUNITS: &[&str] = &[
    "icu", "ward", "clinic", "lab", "outpatient unit", "inpatient unit",
    "emergency room", "operating theatre", "recovery room", "day unit",
    "research group", "imaging suite", "triage desk", "records office",
    "blood bank", "isolation ward", "observation unit", "consultation room",
];

/// Modifiers for composing distinct sub-unit names.
pub const MODIFIERS: &[&str] = &[
    "north", "south", "east", "west", "central", "upper", "lower",
    "first", "second", "third", "fourth", "new", "old", "main", "annex",
    "red", "blue", "green", "amber", "acute", "chronic", "rapid",
];

/// Hospital name parts (tree roots — unique per tree).
pub const HOSPITAL_FIRST: &[&str] = &[
    "mercy", "saint jude", "riverside", "lakeview", "hillcrest",
    "northgate", "westfield", "eastbrook", "southport", "granite",
    "cedar", "willow", "maple", "summit", "harbor", "prairie",
    "valley", "golden gate", "silver lake", "stone bridge",
];

/// Hospital name suffixes.
pub const HOSPITAL_SECOND: &[&str] = &[
    "general hospital", "medical center", "community hospital",
    "university hospital", "regional clinic", "memorial hospital",
    "children's hospital", "teaching hospital",
];

/// Org-chart (UNHCR-like) division stems.
pub const ORG_DIVISIONS: &[&str] = &[
    "protection division", "operations division", "external relations",
    "resilience service", "emergency service", "field support",
    "supply service", "legal affairs", "policy service", "data service",
    "resettlement service", "registration service", "logistics cell",
    "program unit", "liaison office", "coordination cell",
];

/// Org-chart regional offices.
pub const ORG_REGIONS: &[&str] = &[
    "east africa bureau", "west africa bureau", "middle east bureau",
    "asia pacific bureau", "europe bureau", "americas bureau",
    "central asia bureau", "southern africa bureau",
];

/// Org-chart sub-teams.
pub const ORG_TEAMS: &[&str] = &[
    "field office", "sub office", "country team", "desk", "task force",
    "working group", "secretariat", "focal point",
];

/// Question templates (`{e}` replaced by an entity mention).
pub const QUERY_TEMPLATES: &[&str] = &[
    "where does {e} sit in the organization",
    "which units report to {e} and who oversees it",
    "describe the hierarchy around {e}",
    "what is the parent unit of {e}",
    "list the structure above and below {e}",
];
