//! Experiment drivers reproducing every table and figure in the paper's
//! evaluation (§4.5). Each driver prints the paper-style rows and returns
//! a CSV table written by the corresponding bench target to `results/`.
//!
//! Paper-vs-measured notes live in EXPERIMENTS.md. Absolute times differ
//! from the paper (their substrate: Python + C++ on an H100 box with a
//! real LLM; ours: pure Rust on CPU) — the *shape* is the reproduction
//! target: algorithm ordering, speedup growth with tree count, CF
//! flatness in query entity count, accuracy invariance.

use std::sync::Arc;

use crate::bench::harness::{bench, fmt_secs, fmt_speedup, print_table};
use crate::data::hospital::{HospitalConfig, HospitalDataset};
use crate::data::workload::{Workload, WorkloadConfig};
use crate::filter::cuckoo::{CuckooConfig, CuckooFilter};
use crate::filter::fingerprint::entity_key;
use crate::forest::{EntityAddress, Forest};
use crate::llm::generator::Generator;
use crate::llm::judge::{judge, Judgement};
use crate::llm::prompt::Prompt;
use crate::rag::config::{Algorithm, RagConfig};
use crate::rag::pipeline::make_retriever;
use crate::retrieval::context::{generate_context, Context};
use crate::runtime::engine::{Engine, NativeEngine};
use crate::util::csv::CsvTable;

/// Shared experiment defaults (paper §4.4–4.5).
#[derive(Clone, Copy, Debug)]
pub struct ExperimentConfig {
    /// Queries per workload (paper: 100 repetitions).
    pub queries: usize,
    /// Timed repeats per measurement.
    pub repeats: usize,
    /// Context levels n.
    pub context_levels: usize,
    /// Zipf exponent for query locality.
    pub zipf_s: f64,
    /// Base seed.
    pub seed: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            queries: 100,
            repeats: 10,
            context_levels: 3,
            zipf_s: 1.1,
            seed: 42,
        }
    }
}

/// Build the hospital forest for a tree count (shared by all drivers).
pub fn experiment_forest(trees: usize, seed: u64) -> Arc<Forest> {
    Arc::new(
        HospitalDataset::generate(HospitalConfig {
            trees,
            seed,
            ..HospitalConfig::default()
        })
        .build_forest(),
    )
}

/// One timed retrieval pass: find every entity of every query.
fn retrieval_pass(
    retriever: &mut (dyn crate::retrieval::Retriever + Send),
    workload: &Workload,
) -> usize {
    let mut found = 0usize;
    let mut buf = Vec::with_capacity(256);
    for q in &workload.queries {
        for e in &q.entities {
            buf.clear();
            retriever.find_into(e, &mut buf);
            found += buf.len();
        }
    }
    found
}

/// Judge answer accuracy for one algorithm over a workload (run once —
/// accuracy is timing-independent).
fn measure_accuracy(
    forest: &Arc<Forest>,
    algorithm: Algorithm,
    workload: &Workload,
    levels: usize,
    engine: &dyn Engine,
) -> f64 {
    let cfg = RagConfig { algorithm, context_levels: levels, ..RagConfig::default() };
    let mut retriever = make_retriever(forest.clone(), &cfg);
    let generator = Generator::new(engine);
    let mut total = Judgement::default();
    for q in &workload.queries {
        let mut ctx = Context::default();
        for e in &q.entities {
            let addrs = retriever.find(e);
            ctx.merge(generate_context(forest, e, &addrs, levels));
        }
        let prompt = Prompt::assemble(Vec::new(), &ctx, &q.text);
        let answer = generator
            .generate(&q.text, &ctx, &prompt)
            .expect("generation");
        total.merge(judge(&answer.text, &q.gold));
    }
    total.accuracy()
}

// ---------------------------------------------------------------------
// Table 1: retrieval time + accuracy vs tree count
// ---------------------------------------------------------------------

/// Reproduce Table 1. Returns the CSV rows.
pub fn table1(cfg: ExperimentConfig, tree_counts: &[usize]) -> CsvTable {
    let engine = NativeEngine::new();
    let mut csv = CsvTable::new(&[
        "trees", "algorithm", "time_s", "acc", "speedup_vs_naive", "found",
    ]);
    let mut rows = Vec::new();

    for &trees in tree_counts {
        let forest = experiment_forest(trees, cfg.seed);
        let workload = Workload::generate(
            &forest,
            WorkloadConfig {
                entities_per_query: 5,
                queries: cfg.queries,
                zipf_s: cfg.zipf_s,
                deep_bias: 0.95,
                seed: cfg.seed ^ trees as u64,
            },
        );
        let mut naive_time = 0.0;
        for alg in Algorithm::ALL {
            let rag = RagConfig { algorithm: alg, ..RagConfig::default() };
            let mut retriever = make_retriever(forest.clone(), &rag);
            let mut found = 0;
            let result = bench(alg.label(), 1, cfg.repeats, || {
                found = retrieval_pass(retriever.as_mut(), &workload);
            });
            let mean = result.mean();
            if alg == Algorithm::Naive {
                naive_time = mean;
            }
            let acc = measure_accuracy(
                &forest, alg, &workload, cfg.context_levels, &engine,
            );
            rows.push(vec![
                trees.to_string(),
                alg.label().to_string(),
                fmt_secs(mean),
                format!("{:.2}", acc * 100.0),
                fmt_speedup(naive_time, mean),
                found.to_string(),
            ]);
            csv.push(&[
                trees.to_string(),
                alg.label().to_string(),
                format!("{mean}"),
                format!("{acc}"),
                format!("{}", naive_time / mean.max(1e-12)),
                found.to_string(),
            ]);
        }
    }
    print_table(
        "Table 1 — retrieval time per 100-query workload (5 entities/query)",
        &["trees", "algorithm", "time_s", "acc_%", "speedup", "found"],
        &rows,
    );
    csv
}

// ---------------------------------------------------------------------
// Table 2: retrieval time vs entities per query (600 trees)
// ---------------------------------------------------------------------

/// Reproduce Table 2. Returns the CSV rows.
pub fn table2(cfg: ExperimentConfig, trees: usize, entity_counts: &[usize]) -> CsvTable {
    let engine = NativeEngine::new();
    let forest = experiment_forest(trees, cfg.seed);
    let mut csv = CsvTable::new(&[
        "entities_per_query", "algorithm", "time_s", "acc", "speedup_vs_naive",
    ]);
    let mut rows = Vec::new();

    for &k in entity_counts {
        let workload = Workload::generate(
            &forest,
            WorkloadConfig {
                entities_per_query: k,
                queries: cfg.queries,
                zipf_s: cfg.zipf_s,
                deep_bias: 0.95,
                seed: cfg.seed ^ (k as u64).rotate_left(17),
            },
        );
        let mut naive_time = 0.0;
        for alg in Algorithm::ALL {
            let rag = RagConfig { algorithm: alg, ..RagConfig::default() };
            let mut retriever = make_retriever(forest.clone(), &rag);
            let result = bench(alg.label(), 1, cfg.repeats, || {
                retrieval_pass(retriever.as_mut(), &workload);
            });
            let mean = result.mean();
            if alg == Algorithm::Naive {
                naive_time = mean;
            }
            let acc = measure_accuracy(
                &forest, alg, &workload, cfg.context_levels, &engine,
            );
            rows.push(vec![
                k.to_string(),
                alg.label().to_string(),
                fmt_secs(mean),
                format!("{:.2}", acc * 100.0),
                fmt_speedup(naive_time, mean),
            ]);
            csv.push(&[
                k.to_string(),
                alg.label().to_string(),
                format!("{mean}"),
                format!("{acc}"),
                format!("{}", naive_time / mean.max(1e-12)),
            ]);
        }
    }
    print_table(
        &format!("Table 2 — retrieval time vs entities/query ({trees} trees)"),
        &["entities", "algorithm", "time_s", "acc_%", "speedup"],
        &rows,
    );
    csv
}

// ---------------------------------------------------------------------
// Figure 5: per-round search time, temperature sorting ablation
// ---------------------------------------------------------------------

/// Reproduce Figure 5: per-round CF retrieval cost across repeated query
/// rounds, sorting on vs off. Two readings per round:
///
/// * `time_s` — wall time of the full retrieval pass;
/// * `probes_per_lookup` — bucket slots scanned per filter lookup, the
///   quantity temperature sorting directly minimizes. At Rust-native
///   speeds one in-bucket probe is ~1 ns, so the paper's seconds-scale
///   wallclock gap (inflated by their Python/C++ boundary) compresses
///   into this mechanism-level metric here (EXPERIMENTS.md discusses).
pub fn fig5(
    cfg: ExperimentConfig,
    settings: &[(usize, usize)], // (trees, entities_per_query)
    rounds: usize,
) -> CsvTable {
    let mut csv = CsvTable::new(&[
        "trees", "entities_per_query", "sorting", "round", "time_s",
        "probes_per_lookup",
    ]);
    let mut rows = Vec::new();

    for &(trees, k) in settings {
        let forest = experiment_forest(trees, cfg.seed);
        let workload = Workload::generate(
            &forest,
            WorkloadConfig {
                entities_per_query: k,
                queries: cfg.queries,
                zipf_s: cfg.zipf_s,
                deep_bias: 0.95,
                seed: cfg.seed ^ (trees as u64) ^ ((k as u64) << 32),
            },
        );
        for sorting in [true, false] {
            // concrete CuckooTRag for probe-count stats access
            let mut retriever =
                crate::retrieval::cuckoo_rag::CuckooTRag::with_config(
                    forest.clone(),
                    CuckooConfig {
                        sort_by_temperature: sorting,
                        ..CuckooConfig::default()
                    },
                );
            use crate::retrieval::Retriever as _;
            for round in 1..=rounds {
                let before = retriever.filter().stats();
                // median of repeats for a stable per-round number
                let result = bench("round", 0, cfg.repeats, || {
                    retrieval_pass(&mut retriever, &workload);
                });
                let after = retriever.filter().stats();
                let lookups = (after.lookups - before.lookups).max(1);
                let probes = (after.slots_probed - before.slots_probed) as f64
                    / lookups as f64;
                // end-of-round maintenance: the paper sorts between rounds
                retriever.maintain();
                let t = result.summary().p50;
                rows.push(vec![
                    trees.to_string(),
                    k.to_string(),
                    if sorting { "on" } else { "off" }.to_string(),
                    round.to_string(),
                    fmt_secs(t),
                    format!("{probes:.3}"),
                ]);
                csv.push(&[
                    trees.to_string(),
                    k.to_string(),
                    sorting.to_string(),
                    round.to_string(),
                    format!("{t}"),
                    format!("{probes}"),
                ]);
            }
        }
    }
    print_table(
        "Figure 5 — CF T-RAG per-round cost (temperature ablation)",
        &["trees", "entities", "sorting", "round", "time_s", "probes/lookup"],
        &rows,
    );
    csv
}

// ---------------------------------------------------------------------
// §4.5.1 error-rate / load-factor analysis
// ---------------------------------------------------------------------

/// Reproduce the error analysis: insert `n` entities into a fixed-size
/// filter, count (a) inserted entities whose lookup is shadowed by a
/// fingerprint collision and (b) foreign-key false positives.
pub fn error_rate(entity_counts: &[usize]) -> CsvTable {
    let mut csv = CsvTable::new(&[
        "entities", "buckets", "load_factor", "shadowed", "fp_rate",
    ]);
    let mut rows = Vec::new();
    for &n in entity_counts {
        let mut cf = CuckooFilter::new(CuckooConfig {
            initial_buckets: 1024,
            load_threshold: 1.01, // hold size fixed like the paper's 1024
            ..CuckooConfig::default()
        });
        let mut keys = Vec::with_capacity(n);
        for i in 0..n {
            let key = entity_key(&format!("entity-{i}"));
            keys.push(key);
            cf.insert(key, &[EntityAddress::new(0, i as u32)]);
        }
        // (a) shadowing: a lookup that would return a *different* entity's
        // block list (same bucket pair, same fingerprint, earlier slot).
        let mut shadowed = 0usize;
        for (i, &key) in keys.iter().enumerate() {
            if let Some(hit) = cf.lookup(key) {
                let addrs = cf.addresses(hit);
                if addrs.first().map(|a| a.node) != Some(i as u32) {
                    shadowed += 1;
                }
            }
        }
        // (b) foreign false positives
        let probes = 100_000usize;
        let fp = (0..probes)
            .filter(|i| cf.contains(entity_key(&format!("foreign-{i}"))))
            .count();
        rows.push(vec![
            n.to_string(),
            cf.buckets().to_string(),
            format!("{:.4}", cf.load_factor()),
            shadowed.to_string(),
            format!("{:.5}", fp as f64 / probes as f64),
        ]);
        csv.push(&[
            n.to_string(),
            cf.buckets().to_string(),
            format!("{}", cf.load_factor()),
            shadowed.to_string(),
            format!("{}", fp as f64 / probes as f64),
        ]);
    }
    print_table(
        "Error analysis — fingerprint collisions vs load (1024 buckets x 4)",
        &["entities", "buckets", "load", "shadowed", "fp_rate"],
        &rows,
    );
    csv
}

// ---------------------------------------------------------------------
// Ablations: design-choice sweeps beyond the paper's Figure 5
// ---------------------------------------------------------------------

/// Ablate bucket slots and fingerprint bits: retrieval time + shadowing.
pub fn ablation(cfg: ExperimentConfig, trees: usize) -> CsvTable {
    let forest = experiment_forest(trees, cfg.seed);
    let workload = Workload::generate(
        &forest,
        WorkloadConfig {
            entities_per_query: 10,
            queries: cfg.queries,
            zipf_s: cfg.zipf_s,
            deep_bias: 0.95,
            seed: cfg.seed,
        },
    );
    let mut csv = CsvTable::new(&[
        "slots", "fp_bits", "sorting", "time_s", "load_factor", "memory_kb",
    ]);
    let mut rows = Vec::new();
    for slots in [2usize, 4, 8] {
        for fp_bits in [8u32, 12, 16] {
            for sorting in [true, false] {
                let rag = RagConfig {
                    algorithm: Algorithm::Cuckoo,
                    cuckoo: CuckooConfig {
                        slots,
                        fingerprint_bits: fp_bits,
                        sort_by_temperature: sorting,
                        ..CuckooConfig::default()
                    },
                    ..RagConfig::default()
                };
                let mut retriever = make_retriever(forest.clone(), &rag);
                // warm temperatures then measure
                retrieval_pass(retriever.as_mut(), &workload);
                retriever.maintain();
                let result = bench("ablation", 1, cfg.repeats, || {
                    retrieval_pass(retriever.as_mut(), &workload);
                });
                let mean = result.mean();
                let bytes = retriever.index_bytes();
                rows.push(vec![
                    slots.to_string(),
                    fp_bits.to_string(),
                    if sorting { "on" } else { "off" }.to_string(),
                    fmt_secs(mean),
                    String::new(),
                    (bytes / 1024).to_string(),
                ]);
                csv.push(&[
                    slots.to_string(),
                    fp_bits.to_string(),
                    sorting.to_string(),
                    format!("{mean}"),
                    String::new(),
                    (bytes / 1024).to_string(),
                ]);
            }
        }
    }
    print_table(
        &format!("Ablation — CF parameters ({trees} trees, 10 entities/query)"),
        &["slots", "fp_bits", "sorting", "time_s", "load", "mem_kb"],
        &rows,
    );
    csv
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny-scale smoke runs of every driver (full scale runs in benches).
    #[test]
    fn drivers_smoke() {
        let cfg = ExperimentConfig {
            queries: 4,
            repeats: 2,
            ..ExperimentConfig::default()
        };
        let t1 = table1(cfg, &[5]);
        assert_eq!(t1.len(), 4, "one row per algorithm");
        let t2 = table2(cfg, 5, &[2]);
        assert_eq!(t2.len(), 4);
        let f5 = fig5(cfg, &[(5, 2)], 2);
        assert_eq!(f5.len(), 2 * 2, "rounds x sorting");
        let er = error_rate(&[100]);
        assert_eq!(er.len(), 1);
    }

    #[test]
    fn speedup_ordering_holds_at_small_scale() {
        let cfg = ExperimentConfig {
            queries: 20,
            repeats: 3,
            ..ExperimentConfig::default()
        };
        let forest = experiment_forest(30, cfg.seed);
        let workload = Workload::generate(
            &forest,
            WorkloadConfig { queries: 20, ..Default::default() },
        );
        let mut times = Vec::new();
        for alg in Algorithm::ALL {
            let rag = RagConfig { algorithm: alg, ..RagConfig::default() };
            let mut r = make_retriever(forest.clone(), &rag);
            let res = bench(alg.label(), 1, 3, || {
                retrieval_pass(r.as_mut(), &workload);
            });
            times.push(res.summary().p50);
        }
        // CF must beat Naive soundly
        assert!(
            times[3] * 3.0 < times[0],
            "cf {} vs naive {}",
            times[3],
            times[0]
        );
    }
}
