//! Pooled TCP connections to one backend.
//!
//! The router keeps a small free list of idle connections per backend
//! so the steady-state query path pays no TCP handshake. Freshly opened
//! sockets get `TCP_NODELAY` (the protocol is one short line each way)
//! and the router's per-backend IO timeouts, which is what turns a slow
//! backend into a bounded, degradable failure instead of a stall.
//!
//! The pool makes no liveness promise for idle connections — a backend
//! restart leaves stale sockets behind — so the consumer
//! (`router/backend.rs`) retries idle-connection failures against a
//! fresh connection before counting the backend as unhealthy.
//!
//! # Examples
//!
//! ```
//! use std::net::TcpListener;
//! use std::time::Duration;
//! use cft_rag::router::pool::ConnPool;
//!
//! // a listener stands in for a backend
//! let listener = TcpListener::bind("127.0.0.1:0").unwrap();
//! let addr = listener.local_addr().unwrap().to_string();
//!
//! let pool = ConnPool::new(
//!     addr,
//!     2, // keep at most two idle sockets
//!     Duration::from_millis(500),
//!     Duration::from_millis(500),
//! );
//! assert!(pool.take_idle().is_none(), "nothing pooled yet");
//! let conn = pool.connect().expect("listener is up");
//! pool.put_back(conn); // after a clean round trip
//! assert_eq!(pool.idle_count(), 1);
//! assert!(pool.take_idle().is_some(), "steady state skips the handshake");
//! ```

use std::io;
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::Mutex;
use std::time::Duration;

/// Idle-connection pool for one backend address.
#[derive(Debug)]
pub struct ConnPool {
    addr: String,
    idle: Mutex<Vec<TcpStream>>,
    max_idle: usize,
    connect_timeout: Duration,
    io_timeout: Duration,
}

impl ConnPool {
    /// New pool for `addr`, keeping at most `max_idle` idle sockets.
    /// Zero timeouts mean "no timeout" (blocking IO).
    pub fn new(
        addr: impl Into<String>,
        max_idle: usize,
        connect_timeout: Duration,
        io_timeout: Duration,
    ) -> Self {
        ConnPool {
            addr: addr.into(),
            idle: Mutex::new(Vec::new()),
            max_idle,
            connect_timeout,
            io_timeout,
        }
    }

    /// The backend address this pool dials.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Pop one idle connection, if any (freshness not guaranteed).
    pub fn take_idle(&self) -> Option<TcpStream> {
        self.idle.lock().unwrap().pop()
    }

    /// Open a fresh connection with the pool's timeouts applied.
    pub fn connect(&self) -> io::Result<TcpStream> {
        let mut last = io::Error::new(
            io::ErrorKind::AddrNotAvailable,
            format!("no addresses resolved for {}", self.addr),
        );
        for sa in self.addr.to_socket_addrs()? {
            match if self.connect_timeout.is_zero() {
                TcpStream::connect(sa)
            } else {
                TcpStream::connect_timeout(&sa, self.connect_timeout)
            } {
                Ok(stream) => {
                    stream.set_nodelay(true).ok();
                    let t = (!self.io_timeout.is_zero()).then_some(self.io_timeout);
                    stream.set_read_timeout(t)?;
                    stream.set_write_timeout(t)?;
                    return Ok(stream);
                }
                Err(e) => last = e,
            }
        }
        Err(last)
    }

    /// Return a connection after a clean round trip (dropped — i.e.
    /// closed — when the pool is already full).
    pub fn put_back(&self, stream: TcpStream) {
        let mut idle = self.idle.lock().unwrap();
        if idle.len() < self.max_idle {
            idle.push(stream);
        }
    }

    /// Drop every idle connection (e.g. after the backend was marked
    /// down, so a recovered backend starts from fresh sockets).
    pub fn clear(&self) {
        self.idle.lock().unwrap().clear();
    }

    /// Idle connections currently pooled.
    pub fn idle_count(&self) -> usize {
        self.idle.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    fn pool_for(listener: &TcpListener, max_idle: usize) -> ConnPool {
        ConnPool::new(
            listener.local_addr().unwrap().to_string(),
            max_idle,
            Duration::from_millis(500),
            Duration::from_millis(500),
        )
    }

    #[test]
    fn connect_checkin_checkout_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let pool = pool_for(&listener, 2);
        assert!(pool.take_idle().is_none());
        let c = pool.connect().expect("listener is up");
        pool.put_back(c);
        assert_eq!(pool.idle_count(), 1);
        assert!(pool.take_idle().is_some());
        assert_eq!(pool.idle_count(), 0);
    }

    #[test]
    fn pool_caps_idle_and_clears() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let pool = pool_for(&listener, 2);
        for _ in 0..4 {
            let c = pool.connect().unwrap();
            pool.put_back(c);
        }
        assert_eq!(pool.idle_count(), 2, "excess connections dropped");
        pool.clear();
        assert_eq!(pool.idle_count(), 0);
    }

    #[test]
    fn connect_to_dead_backend_errors() {
        // bind then drop to get a port that refuses connections
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let pool = ConnPool::new(
            addr,
            1,
            Duration::from_millis(200),
            Duration::from_millis(200),
        );
        assert!(pool.connect().is_err());
    }
}
