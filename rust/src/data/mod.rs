//! Synthetic datasets, corpora, workloads and gold answers — the
//! substitutes for the paper's UNHCR org chart and private hospital
//! histories (see DESIGN.md §Substitutions for the mapping).

pub mod corpus;
pub mod gold;
pub mod hospital;
pub mod orgchart;
pub mod trace;
pub mod vocab;
pub mod workload;

pub use hospital::{Hospital, HospitalConfig, HospitalDataset};
pub use orgchart::{OrgChartConfig, OrgChartDataset};
pub use workload::{Query, Workload, WorkloadConfig};
