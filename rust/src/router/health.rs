//! Per-backend health: passive failure marking on the query path,
//! active probing (the TCP protocol's `\x01stats` control line) with
//! automatic re-admission, all on lock-free atomics so the scatter path
//! can consult health without synchronizing with the prober.
//!
//! Re-admission is **epoch-gated**: a probe reply must parse as JSON
//! *and* report a `partition_epoch` the router's [`EpochGate`] accepts
//! — a backend mid-warm-up, or restarted with a stale partition after
//! the fleet's membership moved on, keeps failing probes until it
//! catches up, instead of being re-admitted to serve the wrong slice
//! of the key space. A durable backend that warm-restarted from its
//! `--data-dir` (`persist/`) comes back *reporting the epoch recorded
//! in its snapshot*, so as long as the ring has not moved on it passes
//! the gate on the first probe and is re-admitted immediately — the
//! O(delta) catch-up (`rebalance::execute_rejoin`) then runs behind an
//! operator `\x01join` without the backend ever leaving the ring.
//!
//! # Examples
//!
//! ```
//! use cft_rag::router::health::{EpochGate, HealthState};
//!
//! // threshold 2: one failure leaves the backend serving, two demote it
//! let h = HealthState::new(2);
//! h.mark_failure();
//! assert!(h.is_healthy());
//! h.mark_failure();
//! assert!(!h.is_healthy());
//! assert!(h.mark_success(), "success re-admits (returns true on the flip)");
//!
//! // the gate accepts the serving epoch, and the next one mid-rebalance
//! let gate = EpochGate::new(0);
//! gate.open(1);
//! assert!(gate.accepts(0) && gate.accepts(1) && !gate.accepts(7));
//! gate.commit(1);
//! assert!(!gate.accepts(0), "pre-rebalance backends are now stale");
//! ```

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::router::backend::{probe_fleet, Backend};
use crate::util::log;

/// Which fleet membership epochs the router currently accepts from a
/// backend's `\x01stats` reply: the **serving** epoch, plus — while a
/// rebalance is in flight — the epoch being rolled out (backends are
/// repartitioned one at a time, so both generations coexist briefly).
/// Lock-free; shared between the router's membership state, the prober,
/// and every [`Backend`].
#[derive(Debug)]
pub struct EpochGate {
    current: AtomicU64,
    pending: AtomicU64,
}

impl EpochGate {
    /// Gate accepting exactly `epoch` (fleet start: 0).
    pub fn new(epoch: u64) -> Self {
        EpochGate {
            current: AtomicU64::new(epoch),
            pending: AtomicU64::new(epoch),
        }
    }

    /// True when a backend reporting `epoch` may serve.
    pub fn accepts(&self, epoch: u64) -> bool {
        epoch == self.current.load(Ordering::Acquire)
            || epoch == self.pending.load(Ordering::Acquire)
    }

    /// Start accepting `next` alongside the current epoch (a rebalance
    /// began rolling the fleet forward).
    pub fn open(&self, next: u64) {
        self.pending.store(next, Ordering::Release);
    }

    /// Move the gate to exactly `epoch` (the rebalance committed; the
    /// old epoch is now stale and its backends fail probes).
    pub fn commit(&self, epoch: u64) {
        self.current.store(epoch, Ordering::Release);
        self.pending.store(epoch, Ordering::Release);
    }

    /// The serving epoch.
    pub fn current(&self) -> u64 {
        self.current.load(Ordering::Acquire)
    }
}

/// Source of the prober's target list. Ring membership is dynamic
/// (backends join and drain at runtime, `router/rebalance.rs`), so the
/// prober re-reads its targets every round instead of capturing a fixed
/// `Vec` at startup.
pub trait ProbeTargets: Send + Sync {
    /// The backends to probe this round.
    fn probe_targets(&self) -> Vec<Arc<Backend>>;
}

impl ProbeTargets for Vec<Arc<Backend>> {
    fn probe_targets(&self) -> Vec<Arc<Backend>> {
        self.clone()
    }
}

/// Health and load observations for one backend. All methods are
/// `&self` and atomic; counters are monitoring-grade (relaxed).
#[derive(Debug)]
pub struct HealthState {
    healthy: AtomicBool,
    consecutive_failures: AtomicU32,
    failure_threshold: u32,
    probes: AtomicU64,
    readmissions: AtomicU64,
    /// Last `requests` gauge read from the backend's `\x01stats` reply
    /// — backend *load*, not just connectivity.
    observed_requests: AtomicU64,
}

impl HealthState {
    /// New state, initially healthy (a backend must fail to be demoted;
    /// starting pessimistic would force every cold start through the
    /// failover path).
    pub fn new(failure_threshold: u32) -> Self {
        HealthState {
            healthy: AtomicBool::new(true),
            consecutive_failures: AtomicU32::new(0),
            failure_threshold: failure_threshold.max(1),
            probes: AtomicU64::new(0),
            readmissions: AtomicU64::new(0),
            observed_requests: AtomicU64::new(0),
        }
    }

    /// Current serving eligibility.
    pub fn is_healthy(&self) -> bool {
        self.healthy.load(Ordering::Acquire)
    }

    /// Record a successful round trip; returns `true` when this
    /// *re-admitted* a backend that was marked down. Only the
    /// epoch-validating probe path may call this — see
    /// [`record_success`](HealthState::record_success).
    pub fn mark_success(&self) -> bool {
        self.consecutive_failures.store(0, Ordering::Relaxed);
        !self.healthy.swap(true, Ordering::AcqRel)
    }

    /// Record a successful round trip **without re-admitting**: the
    /// failure streak resets, but a demoted backend stays demoted.
    /// The query path uses this — query replies carry no partition
    /// epoch, so an answered query must not bypass the [`EpochGate`]
    /// and re-admit a backend the prober demoted for serving a stale
    /// partition. Re-admission goes through [`mark_success`] from the
    /// epoch-validated probe only.
    ///
    /// [`mark_success`]: HealthState::mark_success
    pub fn record_success(&self) {
        self.consecutive_failures.store(0, Ordering::Relaxed);
    }

    /// Record a failed round trip; returns `true` when this crossing of
    /// the failure threshold marked the backend down.
    pub fn mark_failure(&self) -> bool {
        let failures =
            self.consecutive_failures.fetch_add(1, Ordering::Relaxed) + 1;
        if failures >= self.failure_threshold {
            self.healthy.swap(false, Ordering::AcqRel)
        } else {
            false
        }
    }

    /// Record one active probe round (attempted, regardless of outcome).
    pub fn record_probe(&self) {
        self.probes.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a re-admission (for the metrics snapshot).
    pub fn record_readmission(&self) {
        self.readmissions.fetch_add(1, Ordering::Relaxed);
    }

    /// Record the backend's `requests` gauge from a stats probe.
    pub fn record_load(&self, requests: u64) {
        self.observed_requests.store(requests, Ordering::Relaxed);
    }

    /// Last observed backend request counter (0 before any probe).
    pub fn observed_load(&self) -> u64 {
        self.observed_requests.load(Ordering::Relaxed)
    }

    /// Probes attempted so far.
    pub fn probes(&self) -> u64 {
        self.probes.load(Ordering::Relaxed)
    }

    /// Times this backend was re-admitted after being marked down.
    pub fn readmissions(&self) -> u64 {
        self.readmissions.load(Ordering::Relaxed)
    }
}

/// Background prober: every `interval`, one fleet-wide multiplexed
/// `\x01stats` round ([`probe_fleet`] on the shared outbound reactor).
/// Success re-admits a down backend (and refreshes its load
/// gauge); failure demotes it — so a killed backend stops attracting
/// first-attempt traffic within one probe period even with no queries
/// flowing, and rejoins automatically when it comes back.
#[derive(Debug)]
pub struct HealthProber {
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl HealthProber {
    /// Start probing the backends `targets` yields (re-read every
    /// round, so joins and drains take effect immediately); a zero
    /// `interval` disables probing entirely (no thread — deterministic
    /// tests, external checkers).
    pub fn start(
        targets: Arc<dyn ProbeTargets>,
        interval: Duration,
    ) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        if interval.is_zero() {
            return HealthProber { stop, thread: None };
        }
        let thread = {
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("cft-router-prober".into())
                .spawn(move || {
                    while !stop.load(Ordering::Acquire) {
                        // one multiplexed round on the shared outbound
                        // reactor: hung backends time out concurrently
                        probe_fleet(&targets.probe_targets());
                        // sleep in short slices so shutdown is prompt
                        // even with a long probe interval
                        let mut left = interval;
                        while !left.is_zero() && !stop.load(Ordering::Acquire)
                        {
                            let nap = left.min(Duration::from_millis(25));
                            std::thread::sleep(nap);
                            left -= nap;
                        }
                    }
                })
                .expect("spawn health prober")
        };
        HealthProber { stop, thread: Some(thread) }
    }

    /// Stop and join the prober thread (no-op when probing is off).
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.thread.take() {
            if t.join().is_err() {
                log::warn!("health prober panicked");
            }
        }
    }
}

impl Drop for HealthProber {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_and_readmission_transitions() {
        let h = HealthState::new(2);
        assert!(h.is_healthy());
        assert!(!h.mark_failure(), "below threshold: still healthy");
        assert!(h.is_healthy());
        assert!(h.mark_failure(), "threshold crossed: marked down");
        assert!(!h.is_healthy());
        assert!(!h.mark_failure(), "already down: no new transition");
        assert!(h.mark_success(), "success re-admits");
        assert!(h.is_healthy());
        assert!(!h.mark_success(), "already healthy: no transition");
        // one success resets the failure streak
        assert!(!h.mark_failure());
        assert!(h.is_healthy());
    }

    #[test]
    fn load_and_counters() {
        let h = HealthState::new(1);
        assert_eq!(h.observed_load(), 0);
        h.record_load(42);
        h.record_probe();
        h.record_readmission();
        assert_eq!(h.observed_load(), 42);
        assert_eq!(h.probes(), 1);
        assert_eq!(h.readmissions(), 1);
    }

    #[test]
    fn disabled_prober_spawns_nothing_and_shuts_down() {
        let targets: Arc<dyn ProbeTargets> =
            Arc::new(Vec::<Arc<Backend>>::new());
        let mut p = HealthProber::start(targets, Duration::ZERO);
        p.shutdown();
        p.shutdown(); // idempotent
    }

    #[test]
    fn epoch_gate_transitions() {
        let g = EpochGate::new(0);
        assert_eq!(g.current(), 0);
        assert!(g.accepts(0));
        assert!(!g.accepts(1), "future epochs rejected before open()");
        // a rebalance in flight accepts both generations
        g.open(1);
        assert!(g.accepts(0) && g.accepts(1));
        assert_eq!(g.current(), 0, "open() does not advance serving epoch");
        // commit retires the old epoch
        g.commit(1);
        assert!(!g.accepts(0), "stale epoch rejected after commit");
        assert!(g.accepts(1));
        assert_eq!(g.current(), 1);
    }
}
