//! Raw-text pre-processing pipeline (paper §2): hospital history prose →
//! heuristic NER → relationship extraction → relationship filtering →
//! entity forest → retrieval + QA. Demonstrates the §2 path the paper
//! used for its Chinese hospital dataset.
//!
//! Run: `cargo run --release --example hospital_pipeline`

use std::sync::Arc;

use cft_rag::data::corpus::corpus_from_texts;
use cft_rag::data::hospital::{HospitalConfig, HospitalDataset};
use cft_rag::forest::{builder::build_trees, Forest};
use cft_rag::nlp::filter::filter_relations;
use cft_rag::nlp::ner::heuristic_entities;
use cft_rag::nlp::relate::extract_pairs;
use cft_rag::rag::config::{Algorithm, RagConfig};
use cft_rag::rag::pipeline::RagPipeline;
use cft_rag::runtime::engine::NativeEngine;

fn main() {
    // Raw text only — the forest is built purely from extraction.
    let ds = HospitalDataset::generate(HospitalConfig {
        trees: 12,
        ..HospitalConfig::default()
    });
    let documents = ds.documents();
    println!("processing {} raw history documents...\n", documents.len());

    let mut forest = Forest::new();
    let mut extracted = 0usize;
    let mut kept = 0usize;
    for (i, doc) in documents.iter().enumerate() {
        // §2.1 entity recognition (heuristic pass over the raw prose)
        let entities = heuristic_entities(doc);
        // §2.2 relationship extraction (dependency cue patterns)
        let relations = extract_pairs(doc);
        extracted += relations.len();
        // §2.3 relationship filtering (transitive/cycle/self/duplicate)
        let filtered = filter_relations(&relations);
        kept += filtered.len();
        // tree construction
        let idxs = build_trees(&mut forest, &filtered);
        if i < 3 {
            println!(
                "doc {i}: {} entities, {} relations ({} after filtering), {} tree(s)",
                entities.len(),
                relations.len(),
                filtered.len(),
                idxs.len()
            );
        }
    }
    let stats = forest.stats();
    println!(
        "\nforest from raw text: {} trees, {} nodes, {} entities, depth {}",
        stats.trees, stats.nodes, stats.distinct_entities, stats.max_depth
    );
    println!("relations: {extracted} extracted -> {kept} kept");

    // QA over the extracted forest with the CF retriever.
    let forest = Arc::new(forest);
    let mut pipeline = RagPipeline::build(
        forest,
        corpus_from_texts(&documents),
        Arc::new(NativeEngine::new()),
        RagConfig { algorithm: Algorithm::Cuckoo, ..RagConfig::default() },
    )
    .expect("pipeline");

    for query in [
        "where does cardiology sit in the organization",
        "which units report to surgery and who oversees it",
    ] {
        let resp = pipeline.answer(query).expect("answer");
        println!("\nQ: {query}");
        println!(
            "   entities {:?}, {} facts, retrieval {:?}",
            resp.entities,
            resp.context.len(),
            resp.retrieval_time
        );
        let preview: String = resp.answer.text.chars().take(300).collect();
        println!("A: {preview}...");
    }
}
