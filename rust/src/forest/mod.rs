//! The entity forest substrate: interning, arena trees, addresses,
//! construction from extracted relations, and traversal primitives.

pub mod address;
pub mod builder;
#[allow(clippy::module_inception)]
pub mod forest;
pub mod interner;
pub mod traverse;
pub mod tree;

pub use address::EntityAddress;
pub use forest::{Forest, ForestStats};
pub use interner::{EntityId, Interner};
pub use tree::{Node, NodeIdx, Tree};
