//! Subprocess harness for crash-consistency testing: spawns the REAL
//! `cft-rag` binary (not an in-process coordinator) so a test can
//! SIGKILL it at an arbitrary instant — no destructors, no flushes,
//! exactly the failure a durable backend (`persist/`) must survive —
//! then restart it from the same `--data-dir` and interrogate the
//! recovered state over the newline-delimited TCP protocol.
//!
//! Kept under `tests/support/` (not a `tests/*.rs` target of its own)
//! so every integration test that needs a killable backend process can
//! `mod support;` it.

#![allow(dead_code)] // each test binary uses its own subset

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use cft_rag::util::json::Json;

/// Reserve a free loopback port: bind :0, read the assignment, drop
/// the listener. (The tiny window before the subprocess re-binds it is
/// the standard test-harness race; loopback reassignment inside one
/// process tree is effectively never observed in practice.)
pub fn free_port() -> u16 {
    TcpListener::bind("127.0.0.1:0")
        .expect("reserve port")
        .local_addr()
        .unwrap()
        .port()
}

/// One `cft-rag serve` child process bound to `127.0.0.1:{port}`.
///
/// Dropping the handle SIGKILLs and reaps the child — tests that want
/// a *clean* shutdown (final snapshot cut) must call [`stop`] first.
///
/// [`stop`]: BackendProc::stop
pub struct BackendProc {
    child: Child,
    pub addr: String,
    pub data_dir: PathBuf,
}

impl BackendProc {
    /// Spawn `cft-rag serve` with a durable `--data-dir`, plus any
    /// extra CLI arguments, and wait until it accepts connections.
    pub fn spawn(
        port: u16,
        data_dir: &Path,
        extra_args: &[&str],
    ) -> BackendProc {
        let addr = format!("127.0.0.1:{port}");
        let child = Command::new(env!("CARGO_BIN_EXE_cft-rag"))
            .arg("serve")
            .args(["--port", &port.to_string()])
            .args(["--trees", "12"])
            .args(["--workers", "2"])
            .args(["--engine", "native"])
            .args(["--data-dir", &data_dir.display().to_string()])
            .args(extra_args)
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn cft-rag serve");
        let mut proc = BackendProc {
            child,
            addr,
            data_dir: data_dir.to_path_buf(),
        };
        proc.wait_listening(Duration::from_secs(30));
        proc
    }

    /// Poll-connect until the child accepts (the listen banner prints
    /// *before* the bind, so connecting is the only reliable signal).
    fn wait_listening(&mut self, timeout: Duration) {
        let deadline = Instant::now() + timeout;
        loop {
            if TcpStream::connect(&self.addr).is_ok() {
                return;
            }
            if let Ok(Some(status)) = self.child.try_wait() {
                panic!("backend exited before listening: {status}");
            }
            if Instant::now() >= deadline {
                let _ = self.child.kill();
                let _ = self.child.wait();
                panic!("backend never listened on {}", self.addr);
            }
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    /// A fresh protocol connection to the child.
    pub fn client(&self) -> Client {
        Client::connect(&self.addr).expect("connect to backend")
    }

    /// SIGKILL — no shutdown path runs, no buffers flush. This is the
    /// crash under test.
    pub fn kill(&mut self) {
        let _ = self.child.kill(); // SIGKILL on unix
        let _ = self.child.wait();
    }
}

impl Drop for BackendProc {
    fn drop(&mut self) {
        self.kill();
    }
}

/// One persistent connection speaking the newline-delimited protocol:
/// send a line, read the one-line JSON reply.
pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> std::io::Result<Client> {
        let writer = TcpStream::connect(addr)?;
        writer.set_nodelay(true)?;
        let reader = BufReader::new(writer.try_clone()?);
        Ok(Client { writer, reader })
    }

    /// Send `line` and read the acknowledging reply. Panics on a
    /// non-JSON reply — every control line acks with one JSON line.
    pub fn send(&mut self, line: &str) -> Json {
        self.send_no_reply(line);
        self.read_reply()
    }

    /// Write `line` without waiting for its ack — the "crash with an
    /// op in flight" half of a kill-point schedule.
    pub fn send_no_reply(&mut self, line: &str) {
        self.writer
            .write_all(format!("{line}\n").as_bytes())
            .expect("write line");
        self.writer.flush().expect("flush line");
    }

    /// Read one JSON reply line.
    pub fn read_reply(&mut self) -> Json {
        let mut buf = String::new();
        let n = self.reader.read_line(&mut buf).expect("read reply");
        assert!(n > 0, "backend closed the connection mid-reply");
        Json::parse(buf.trim_end())
            .unwrap_or_else(|e| panic!("non-JSON reply {buf:?}: {e}"))
    }

    /// `\x01insert tree node entity`, acked.
    pub fn insert(&mut self, entity: &str, tree: u32, node: u32) -> Json {
        self.send(&format!("\x01insert {tree} {node} {entity}"))
    }

    /// `\x01delete entity`, acked.
    pub fn delete(&mut self, entity: &str) -> Json {
        self.send(&format!("\x01delete {entity}"))
    }

    /// `\x01dump entity` → the sorted (tree, node) address list.
    pub fn dump(&mut self, entity: &str) -> Vec<(u32, u32)> {
        let reply = self.send(&format!("\x01dump {entity}"));
        assert_eq!(
            reply.get("ok"),
            Some(&Json::Bool(true)),
            "dump {entity}: {reply}"
        );
        let mut out: Vec<(u32, u32)> = reply
            .get("addresses")
            .and_then(Json::as_arr)
            .unwrap_or_else(|| panic!("dump without addresses: {reply}"))
            .iter()
            .map(|a| {
                (
                    a.get("tree").and_then(Json::as_f64).unwrap() as u32,
                    a.get("node").and_then(Json::as_f64).unwrap() as u32,
                )
            })
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// `\x01stats`, acked.
    pub fn stats(&mut self) -> Json {
        self.send("\x01stats")
    }
}

/// A unique scratch directory under the system temp dir; pre-cleaned
/// so a rerun never inherits a previous run's state.
pub fn scratch_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("cft-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}
