//! Per-node subtree Bloom annotations — the substrate of the BF / BF2
//! T-RAG baselines (paper §4.1): "we incorporate a Bloom Filter at each
//! node in the entity tree [indicating] whether an entity exists in the
//! node or its descendants. During retrieval, if a Bloom Filter suggests
//! that an entity is absent, the search path is pruned."

use crate::filter::bloom::BloomFilter;
use crate::filter::fingerprint::entity_key;
use crate::forest::{Forest, NodeIdx};

/// Bloom filters for every node of every tree in a forest.
#[derive(Clone, Debug)]
pub struct BloomForest {
    /// `blooms[tree][node]` — subtree membership filter.
    blooms: Vec<Vec<BloomFilter>>,
}

impl BloomForest {
    /// Annotate `forest` with subtree blooms at the given target
    /// false-positive rate. All nodes of one tree share a sizing (the
    /// tree's node count) so parent filters can be unioned from children.
    pub fn build(forest: &Forest, fp_rate: f64) -> Self {
        let mut blooms = Vec::with_capacity(forest.len());
        for tree in forest.trees() {
            let n = tree.len();
            let mut per_node: Vec<BloomFilter> =
                (0..n).map(|_| BloomFilter::new(n, fp_rate)).collect();
            // children always have larger arena indices than their parent,
            // so one reverse pass builds bottom-up.
            for idx in (0..n).rev() {
                let key = entity_key(forest.entity_name(tree.entity(idx as NodeIdx)));
                per_node[idx].insert(key);
                let node = tree.node(idx as NodeIdx);
                // union children into this node (children already final)
                for &c in &node.children {
                    let (head, tail) = per_node.split_at_mut(c as usize);
                    head[idx].union(&tail[0]);
                }
            }
            blooms.push(per_node);
        }
        BloomForest { blooms }
    }

    /// Might `key` occur at `node` or anywhere below it?
    #[inline]
    pub fn might_contain(&self, tree: u32, node: NodeIdx, key: u64) -> bool {
        self.blooms[tree as usize][node as usize].contains(key)
    }

    /// Total heap bytes across all node filters.
    pub fn memory_bytes(&self) -> usize {
        self.blooms
            .iter()
            .flat_map(|t| t.iter().map(BloomFilter::memory_bytes))
            .sum()
    }

    /// Total number of node filters.
    pub fn filters(&self) -> usize {
        self.blooms.iter().map(Vec::len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::forest::Tree;

    /// hospital -> {cardiology -> {icu}, surgery}
    fn forest() -> Forest {
        let mut f = Forest::new();
        let h = f.intern("hospital");
        let c = f.intern("cardiology");
        let s = f.intern("surgery");
        let i = f.intern("icu");
        let mut t = Tree::with_root(h);
        let cn = t.add_child(0, c);
        t.add_child(0, s);
        t.add_child(cn, i);
        f.add_tree(t);
        f
    }

    #[test]
    fn root_bloom_covers_whole_tree() {
        let f = forest();
        let bf = BloomForest::build(&f, 0.01);
        for name in ["hospital", "cardiology", "surgery", "icu"] {
            assert!(bf.might_contain(0, 0, entity_key(name)), "{name}");
        }
    }

    #[test]
    fn subtree_blooms_scoped() {
        let f = forest();
        let bf = BloomForest::build(&f, 0.001);
        let card_node = 1; // insertion order: root=0, cardiology=1
        // members can never false-negative: these asserts are exact
        assert!(bf.might_contain(0, card_node, entity_key("icu")));
        assert!(bf.might_contain(0, card_node, entity_key("cardiology")));
        // Non-members ("surgery" is a sibling, not under cardiology) are
        // only *probabilistically* absent: hard-asserting any single
        // negative flakes at the configured false-positive rate. Assert
        // the scoping under a tolerance instead: out of the sibling plus
        // 500 foreign names, at 0.1% fp we expect ~0.5 positives — 5 is
        // a >6-sigma bound while still proving the filter is scoped to
        // the subtree rather than the whole tree.
        let false_positives = std::iter::once("surgery".to_string())
            .chain((0..500).map(|i| format!("foreign-dept-{i}")))
            .filter(|name| bf.might_contain(0, card_node, entity_key(name)))
            .count();
        assert!(
            false_positives <= 5,
            "subtree bloom not scoped: {false_positives}/501 outsiders matched"
        );
    }

    #[test]
    fn absent_entity_pruned() {
        let f = forest();
        let bf = BloomForest::build(&f, 0.001);
        // same tolerance rationale as subtree_blooms_scoped: assert the
        // pruning property over many absent probes, not one exact bit
        let false_positives = (0..500)
            .map(|i| format!("absent-{i}"))
            .filter(|name| bf.might_contain(0, 0, entity_key(name)))
            .count();
        assert!(
            false_positives <= 5,
            "root bloom admits too many absents: {false_positives}/500"
        );
    }

    #[test]
    fn filter_count_matches_nodes() {
        let f = forest();
        let bf = BloomForest::build(&f, 0.01);
        assert_eq!(bf.filters(), f.total_nodes());
        assert!(bf.memory_bytes() > 0);
    }
}
