//! The CFT-RAG pipeline (paper Figure 1) and its configuration.
//!
//! [`pipeline::RagPipeline`] assembles the whole single-process flow —
//! query → vector search → gazetteer NER → tree retrieval (the
//! configured [`Algorithm`]) → context generation → prompt assembly →
//! answer generation — and [`config::RagConfig`] is the one knob bag
//! every layer above reads: algorithm choice, context depth, Cuckoo
//! filter tuning, in-process shard count, and (for R-way replicated
//! fleets) the [`config::KeyPartition`] that restricts a backend's
//! index to its slice of the entity-key space.
//!
//! The same config also drives the serving layers: the coordinator
//! builds its shared retriever through
//! [`pipeline::make_concurrent_retriever`], and the shard router's
//! [`config::RouterConfig`] lives here too so one module owns every
//! deployment decision. See the repo-level `README.md` for how the
//! layers stack and `docs/PROTOCOL.md` for the wire protocol between
//! them.

pub mod config;
pub mod pipeline;

pub use config::{Algorithm, KeyPartition, RagConfig, RouterConfig};
pub use pipeline::{
    make_concurrent_retriever, make_retriever, RagPipeline, RagResponse,
};
