//! End-to-end pipeline + coordinator integration: dataset → forest →
//! (PJRT artifacts when present, else native engine) → retrieval →
//! generation → judged accuracy, plus coordinator batching under load.

use std::sync::Arc;

use cft_rag::coordinator::{Coordinator, CoordinatorConfig};
use cft_rag::data::corpus::corpus_from_texts;
use cft_rag::data::hospital::{HospitalConfig, HospitalDataset};
use cft_rag::data::workload::{Workload, WorkloadConfig};
use cft_rag::llm::judge::{judge, Judgement};
use cft_rag::rag::config::{Algorithm, RagConfig};
use cft_rag::rag::pipeline::RagPipeline;
use cft_rag::runtime::engine::{Engine, NativeEngine, PjrtEngine};
use cft_rag::runtime::{default_dir, Runtime};

fn engine() -> Arc<dyn Engine> {
    match Runtime::load(default_dir()) {
        Ok(rt) => Arc::new(PjrtEngine::new(rt)),
        Err(_) => {
            eprintln!("NOTE: artifacts missing; using native engine");
            Arc::new(NativeEngine::new())
        }
    }
}

fn dataset(trees: usize) -> (HospitalDataset, Arc<cft_rag::forest::Forest>) {
    let ds = HospitalDataset::generate(HospitalConfig {
        trees,
        ..HospitalConfig::default()
    });
    let forest = Arc::new(ds.build_forest());
    (ds, forest)
}

#[test]
fn pipeline_accuracy_in_plateau_band() {
    let (ds, forest) = dataset(12);
    let workload = Workload::generate(
        &forest,
        WorkloadConfig { queries: 25, ..Default::default() },
    );
    let mut accuracies = Vec::new();
    for alg in Algorithm::ALL {
        let mut pipeline = RagPipeline::build(
            forest.clone(),
            corpus_from_texts(&ds.documents()),
            engine(),
            RagConfig { algorithm: alg, ..RagConfig::default() },
        )
        .unwrap();
        let mut total = Judgement::default();
        for q in &workload.queries {
            let resp = pipeline.answer(&q.text).unwrap();
            total.merge(judge(&resp.answer.text, &q.gold));
        }
        let acc = total.accuracy();
        // the n=3 window over depth-4..6 trees should land broadly near
        // the paper's ~0.66 plateau; wide band for workload noise
        assert!(
            (0.4..=1.0).contains(&acc),
            "{}: accuracy {acc}",
            alg.label()
        );
        accuracies.push(acc);
    }
    // accuracy must be algorithm-invariant (the paper's key claim)
    let max = accuracies.iter().cloned().fold(f64::MIN, f64::max);
    let min = accuracies.iter().cloned().fold(f64::MAX, f64::min);
    assert!(
        max - min < 0.02,
        "accuracy differs across algorithms: {accuracies:?}"
    );
}

#[test]
fn pipeline_end_to_end_with_docs() {
    let (ds, forest) = dataset(8);
    let mut pipeline = RagPipeline::build(
        forest,
        corpus_from_texts(&ds.documents()),
        engine(),
        RagConfig::default(),
    )
    .unwrap();
    let resp = pipeline
        .answer("where does cardiology sit in the organization")
        .unwrap();
    assert!(!resp.retrieved_docs.is_empty(), "vector search returned docs");
    assert!(resp.entities.contains(&"cardiology".to_string()));
    assert!(resp.context.len() > 0);
    assert!(resp.answer.text.contains("cardiology"));
    assert!(resp.retrieval_time <= resp.total_time);
}

#[test]
fn coordinator_under_concurrent_load() {
    let (ds, forest) = dataset(10);
    let workload = Workload::generate(
        &forest,
        WorkloadConfig { queries: 40, ..Default::default() },
    );
    let coordinator = Coordinator::start(
        forest,
        corpus_from_texts(&ds.documents()),
        engine(),
        RagConfig::default(),
        CoordinatorConfig { workers: 3, ..Default::default() },
    )
    .unwrap();

    let rxs: Vec<_> = workload
        .queries
        .iter()
        .map(|q| coordinator.submit(&q.text).expect("submit"))
        .collect();
    let mut ok = 0;
    for rx in rxs {
        let resp = rx.recv().unwrap().unwrap();
        assert!(!resp.answer.is_empty());
        ok += 1;
    }
    assert_eq!(ok, 40);
    let snap = coordinator.metrics().snapshot();
    assert_eq!(snap.requests, 40);
    assert_eq!(snap.failures, 0);
    assert!(snap.batches <= 40, "batching collapsed queries");
    assert!(snap.mean_batch_fill >= 1.0);
    coordinator.shutdown();
}

#[test]
fn cuckoo_dynamic_updates_visible_e2e() {
    let (ds, forest) = dataset(5);
    let mut pipeline = RagPipeline::build(
        forest.clone(),
        corpus_from_texts(&ds.documents()),
        engine(),
        RagConfig { algorithm: Algorithm::Cuckoo, ..RagConfig::default() },
    )
    .unwrap();
    // entity present initially
    let before = pipeline
        .answer("describe the hierarchy around cardiology")
        .unwrap();
    assert!(before.context.len() > 0);
    // retriever-level delete (paper Algorithm 2) — downcast via trait obj
    // is not exposed; exercise via a fresh CuckooTRag instead
    use cft_rag::retrieval::cuckoo_rag::CuckooTRag;
    use cft_rag::retrieval::Retriever;
    let mut r = CuckooTRag::new(forest);
    assert!(!r.find("cardiology").is_empty());
    assert!(r.remove_entity("cardiology"));
    assert!(r.find("cardiology").is_empty());
}
