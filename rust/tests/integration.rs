//! Cross-module integration: raw text → §2 pre-processing → forest →
//! all retrievers → context → prompt → generation → judge, asserting
//! stage-to-stage contracts that unit tests cannot see.

use std::sync::Arc;

use cft_rag::data::hospital::{HospitalConfig, HospitalDataset};
use cft_rag::forest::{builder::build_trees, Forest};
use cft_rag::llm::generator::Generator;
use cft_rag::llm::judge::judge;
use cft_rag::llm::prompt::Prompt;
use cft_rag::nlp::filter::filter_relations;
use cft_rag::nlp::ner::GazetteerNer;
use cft_rag::nlp::relate::extract_pairs;
use cft_rag::rag::config::{Algorithm, RagConfig};
use cft_rag::rag::pipeline::make_retriever;
use cft_rag::retrieval::context::generate_context;
use cft_rag::runtime::engine::NativeEngine;

/// The full §2 path on generated raw text must produce a forest whose
/// retrieval results match the tuple-built forest for shared entities.
#[test]
fn raw_text_forest_matches_tuple_forest_semantics() {
    let ds = HospitalDataset::generate(HospitalConfig {
        trees: 6,
        ..HospitalConfig::default()
    });

    // tuple-built (ground truth)
    let truth = ds.build_forest();

    // text-built (extraction path)
    let mut extracted_forest = Forest::new();
    for h in &ds.hospitals {
        let pairs = extract_pairs(&h.history);
        let filtered = filter_relations(&pairs);
        build_trees(&mut extracted_forest, &filtered);
    }

    // every department of every hospital must be findable in both with
    // the same parent chain (top levels are the strongest signal)
    let mut checked = 0;
    for h in &ds.hospitals {
        for (child, parent) in h.relations.iter().take(8) {
            let (Some(tid), Some(eid)) = (
                truth.entity_id(child),
                extracted_forest.entity_id(child),
            ) else {
                continue;
            };
            let t_addr = truth.scan_addresses(tid);
            let e_addr = extracted_forest.scan_addresses(eid);
            assert!(!t_addr.is_empty());
            if e_addr.is_empty() {
                continue; // extraction may drop a few; coverage test below
            }
            // parent matches in at least one occurrence
            let t_parents: Vec<String> = t_addr
                .iter()
                .flat_map(|&a| {
                    cft_rag::forest::traverse::ancestors(&truth, a, 1)
                        .into_iter()
                        .map(|p| truth.entity_name(p).to_string())
                })
                .collect();
            let e_parents: Vec<String> = e_addr
                .iter()
                .flat_map(|&a| {
                    cft_rag::forest::traverse::ancestors(&extracted_forest, a, 1)
                        .into_iter()
                        .map(|p| extracted_forest.entity_name(p).to_string())
                })
                .collect();
            if t_parents.iter().any(|p| p == parent) {
                assert!(
                    e_parents.iter().any(|p| p == parent),
                    "extracted forest lost {child} -> {parent} (has {e_parents:?})"
                );
                checked += 1;
            }
        }
    }
    assert!(checked > 10, "only {checked} relations cross-checked");
}

/// NER over workload queries must recover the planted entities, and the
/// retriever + context + generator + judge chain must recall answerable
/// facts perfectly for a known query.
#[test]
fn ner_to_judge_chain_exact_on_known_query() {
    let ds = HospitalDataset::generate(HospitalConfig {
        trees: 6,
        ..HospitalConfig::default()
    });
    let forest = Arc::new(ds.build_forest());
    let ner = GazetteerNer::new(forest.interner().iter().map(|(_, n)| n));

    // take a mid-depth entity with a parent
    let table = forest.address_table();
    let (eid, _) = table
        .iter()
        .find(|(id, addrs)| {
            !addrs.is_empty()
                && forest.tree(addrs[0].tree).node(addrs[0].node).depth >= 2
                && forest.entity_name(**id).len() > 6
        })
        .expect("some deep entity");
    let name = forest.entity_name(*eid).to_string();

    let query = format!("what is the parent unit of {name}");
    let found = ner.recognize(&query);
    assert!(found.contains(&name), "NER missed '{name}' in '{query}'");

    let mut retriever = make_retriever(
        forest.clone(),
        &RagConfig { algorithm: Algorithm::Cuckoo, ..RagConfig::default() },
    );
    let addrs = retriever.find(&name);
    let ctx = generate_context(&forest, &name, &addrs, 3);
    assert!(!ctx.is_empty());

    let engine = NativeEngine::new();
    let generator = Generator::new(&engine);
    let prompt = Prompt::assemble(vec![], &ctx, &query);
    let answer = generator.generate(&query, &ctx, &prompt).unwrap();

    // all gold facts within 3 levels must be recalled
    let gold: Vec<_> = cft_rag::data::gold::gold_for_entity(&forest, &name)
        .into_iter()
        .filter(|g| g.distance <= 3)
        .collect();
    assert!(!gold.is_empty());
    let j = judge(&answer.text, &gold);
    assert_eq!(
        j.gold_recalled,
        j.gold_total,
        "answerable gold must be fully recalled: {answer:?}"
    );
}

/// Deleting an entity from the CF must not disturb other entities even
/// across maintenance and re-insertion cycles (dynamic-update story).
#[test]
fn dynamic_updates_leave_neighbors_intact() {
    use cft_rag::retrieval::cuckoo_rag::CuckooTRag;
    use cft_rag::retrieval::Retriever;

    let forest = Arc::new(
        HospitalDataset::generate(HospitalConfig {
            trees: 10,
            ..HospitalConfig::default()
        })
        .build_forest(),
    );
    let mut r = CuckooTRag::new(forest.clone());
    let names: Vec<String> = forest
        .interner()
        .iter()
        .map(|(_, n)| n.to_string())
        .take(50)
        .collect();
    let before: Vec<usize> = names.iter().map(|n| r.find(n).len()).collect();

    // delete every third entity
    for name in names.iter().step_by(3) {
        assert!(r.remove_entity(name));
    }
    r.maintain();
    for (i, name) in names.iter().enumerate() {
        let now = r.find(name).len();
        if i % 3 == 0 {
            assert_eq!(now, 0, "{name} should be gone");
        } else {
            assert_eq!(now, before[i], "{name} disturbed by deletes");
        }
    }
    // re-insert the deleted ones via dynamic occurrence registration
    for (i, name) in names.iter().enumerate() {
        if i % 3 == 0 {
            let id = forest.entity_id(name).unwrap();
            for a in forest.scan_addresses(id) {
                r.add_occurrence(name, a);
            }
            assert_eq!(r.find(name).len(), before[i], "{name} restored");
        }
    }
}
