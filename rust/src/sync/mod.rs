//! Synchronization primitives for the concurrency core — std by
//! default, model-checkable on demand.
//!
//! Every lock, atomic, channel, thread-spawn and clock the
//! filter/coordinator concurrency core uses is imported from here
//! instead of `std::sync`/`std::thread`/`std::time`:
//!
//! * **Default build**: everything in this module is a verbatim
//!   re-export of the std item — zero cost, zero behavior change (the
//!   release binary is bit-for-bit the same code it was before this
//!   module existed).
//! * **`--features modelcheck`**: the same names resolve to thin
//!   wrappers that route every acquire/release/load/store/send/park
//!   through the seeded cooperative scheduler in [`crate::modelcheck`],
//!   turning a multi-threaded test into a deterministic, replayable
//!   exploration of interleavings (see `docs/TESTING.md`).
//!
//! The wrappers **pass through to std behavior on any thread that is
//! not part of a model run** (scheduler presence is thread-local), so
//! `cargo test --features modelcheck` still runs the ordinary suite —
//! TCP integration tests included — unchanged; only bodies executed
//! under [`crate::modelcheck::explore`] get scheduled.
//!
//! Two usage rules under the feature (irrelevant to default builds):
//! primitives created inside a model run must not escape it, and a
//! primitive must not be shared between model vthreads and ordinary
//! threads (the shim panics with a clear message if that happens).
#![warn(missing_debug_implementations)]

// Shared std error vocabulary: the shim guards reuse std's poisoning
// and try-lock error types, so caller code is identical either way.
pub use std::sync::{LockResult, PoisonError, TryLockError, TryLockResult};

#[cfg(not(feature = "modelcheck"))]
pub use std::sync::{
    atomic, mpsc, Arc, Mutex, MutexGuard, RwLock, RwLockReadGuard,
    RwLockWriteGuard,
};
#[cfg(not(feature = "modelcheck"))]
pub use std::thread;

#[cfg(feature = "modelcheck")]
mod locks;
#[cfg(feature = "modelcheck")]
pub use locks::{
    Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard,
};
#[cfg(feature = "modelcheck")]
pub mod atomic;
#[cfg(feature = "modelcheck")]
pub mod mpsc;
#[cfg(feature = "modelcheck")]
pub mod thread;
// `Arc` needs no instrumentation: clone/drop are not interleaving
// decisions the model needs to control (loom tracks them to validate
// memory reclamation; our checker targets lock/channel schedules).
#[cfg(feature = "modelcheck")]
pub use std::sync::Arc;

pub mod time;

/// Scheduler hints for instrumented hot paths.
pub mod hint {
    //! Explicit interleaving points.
    //!
    //! Long critical sections (incremental migration, maintenance
    //! application) call [`preemption_point`] between steps so the
    //! model checker can interleave other vthreads at step granularity
    //! instead of only at lock boundaries. Compiles to nothing without
    //! the `modelcheck` feature.

    /// Mark a point where the cooperative scheduler may preempt.
    /// No-op (inlined away) in default builds; under `modelcheck` it
    /// yields to the scheduler when the calling thread is part of a
    /// model run.
    #[inline(always)]
    pub fn preemption_point() {
        #[cfg(feature = "modelcheck")]
        {
            if let Some((sh, vtid)) = crate::modelcheck::managed() {
                sh.yield_point(vtid);
            }
        }
    }
}
