//! L3 serving coordinator: one process that turns the single-threaded
//! pipeline into a multi-client server.
//!
//! A coordinator owns four cooperating stages (diagram in `server.rs`):
//! a bounded **submit queue** with explicit backpressure, a **dynamic
//! batcher** that embeds and vector-searches admitted queries at the
//! engine's batch size, a **worker pool** that runs NER → tree
//! retrieval → context → generation per query against a shared
//! [`ConcurrentRetriever`](crate::retrieval::ConcurrentRetriever)
//! (per-shard read locks, no global retriever mutex), and a
//! **maintainer thread** that drains filter migrations and temperature
//! re-sorts off the hot path.
//!
//! The TCP front end (`tcp.rs`) exposes all of it over the
//! newline-delimited line protocol specified in `docs/PROTOCOL.md`:
//! query lines, the `\x01stats` load/health snapshot, and the
//! `\x01insert` / `\x01delete` dynamic index updates that the L4 shard
//! router (`router/`) broadcasts to a key's replica set. A coordinator
//! started with a [`KeyPartition`](crate::rag::config::KeyPartition)
//! indexes only its owned slice of the entity-key space — the
//! partitioned-backend half of the router's R-way replication story.

pub mod batcher;
pub mod metrics;
pub mod server;
pub mod tcp;

pub use batcher::{collect_batch, BatchOutcome, BatchPolicy};
pub use metrics::{Metrics, MetricsSnapshot};
pub use server::{Coordinator, CoordinatorConfig, ServeResponse};
