//! Property-based tests for the persistence formats (`persist/`):
//!
//! - **snapshot roundtrip** — encode → decode (and write-atomic →
//!   load) preserves epoch, membership, temperatures and address
//!   lists exactly, including through a live cuckoo filter;
//! - **torn-tail truncation** — an op log cut at ANY byte replays to
//!   exactly the longest prefix of complete records, never an error
//!   (a torn tail is what a crash legitimately leaves behind);
//! - **single-bit corruption** — a snapshot with any one bit flipped
//!   is refused (checksum), never silently loaded; a corrupted op log
//!   either refuses loudly or yields a clean *prefix* of what was
//!   written (a flipped length field can mimic a torn tail, which
//!   truncates — it can never fabricate or reorder operations).
//!
//! Harness: the in-crate `util::proptest` (seed override via
//! `CFT_PROPTEST_SEED`, shrinking on failure) — no external deps.

use cft_rag::filter::cuckoo::{CuckooConfig, CuckooFilter};
use cft_rag::forest::EntityAddress;
use cft_rag::persist::oplog::{replay_bytes, LogOp, TailOutcome};
use cft_rag::persist::snapshot::{self, Snapshot};
use cft_rag::util::proptest::{forall, forall_simple, shrink_vec, Config};
use cft_rag::util::rng::Rng;

fn gen_addrs(rng: &mut Rng, max: usize) -> Vec<EntityAddress> {
    (0..rng.below(max as u64 + 1))
        .map(|_| {
            EntityAddress::new(rng.below(500) as u32, rng.below(500) as u32)
        })
        .collect()
}

fn gen_snapshot(rng: &mut Rng) -> Snapshot {
    // unique keys via BTreeMap (the filter never exports duplicates)
    let n = rng.below(30) as usize;
    let mut entries = std::collections::BTreeMap::new();
    for _ in 0..n {
        entries.insert(
            rng.next_u64(),
            (rng.below(10_000) as u32, gen_addrs(rng, 6)),
        );
    }
    Snapshot {
        partition_epoch: rng.next_u64(),
        entries: entries
            .into_iter()
            .map(|(k, (t, a))| (k, t, a))
            .collect(),
    }
}

fn gen_ops(rng: &mut Rng, max_len: usize) -> Vec<LogOp> {
    let n = rng.range(1, max_len + 1);
    (0..n)
        .map(|_| {
            let entity = format!("entity-{}", rng.below(50));
            match rng.below(4) {
                0 => LogOp::Delete { entity },
                1 => LogOp::Epoch(rng.next_u64()),
                _ => LogOp::Insert {
                    entity,
                    addr: EntityAddress::new(
                        rng.below(64) as u32,
                        rng.below(64) as u32,
                    ),
                },
            }
        })
        .collect()
}

fn encode_log(ops: &[LogOp]) -> Vec<u8> {
    ops.iter().flat_map(|op| op.encode()).collect()
}

#[test]
fn snapshot_roundtrips_through_bytes_and_disk() {
    let path = std::env::temp_dir()
        .join(format!("cft-prop-snap-{}.cft", std::process::id()));
    forall_simple(
        60,
        |rng| gen_snapshot(rng),
        |snap| {
            let decoded = Snapshot::from_bytes(&snap.to_bytes())
                .map_err(|e| format!("decode of clean bytes failed: {e}"))?;
            if &decoded != snap {
                return Err(format!("byte roundtrip lost state: {snap:?}"));
            }
            snapshot::write_atomic(&path, snap)
                .map_err(|e| format!("write_atomic: {e}"))?;
            let loaded = snapshot::load(&path)
                .map_err(|e| format!("load of clean snapshot failed: {e}"))?;
            if &loaded != snap {
                return Err("disk roundtrip lost state".into());
            }
            Ok(())
        },
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn snapshot_roundtrips_through_a_live_filter() {
    // membership, temperatures AND address lists survive
    // export → snapshot bytes → restore into a FRESH filter
    forall_simple(
        30,
        |rng| {
            let n = rng.range(1, 120);
            let mut seen = std::collections::BTreeSet::new();
            (0..n)
                .map(|_| {
                    let mut k = rng.next_u64();
                    while !seen.insert(k) {
                        k = rng.next_u64();
                    }
                    // non-empty: the filter stores no empty entries
                    let mut a = gen_addrs(rng, 4);
                    if a.is_empty() {
                        a.push(EntityAddress::new(1, 1));
                    }
                    (k, rng.below(1000) as u32, a)
                })
                .collect::<Vec<(u64, u32, Vec<EntityAddress>)>>()
        },
        |entries| {
            let mut cf = CuckooFilter::new(CuckooConfig {
                initial_buckets: 4, // force expansions along the way
                ..CuckooConfig::default()
            });
            for (k, t, a) in entries {
                if !cf.insert(*k, a) {
                    return Err(format!("insert {k} rejected"));
                }
                cf.set_temperature(*k, *t);
            }
            let snap = Snapshot {
                partition_epoch: 7,
                entries: cf.export_entries(),
            };
            let decoded = Snapshot::from_bytes(&snap.to_bytes())
                .map_err(|e| format!("decode: {e}"))?;
            let mut restored = CuckooFilter::new(CuckooConfig {
                initial_buckets: 4,
                ..CuckooConfig::default()
            });
            for (k, t, a) in &decoded.entries {
                if !restored.restore_entry(*k, *t, a) {
                    return Err(format!("restore of {k} rejected"));
                }
            }
            let canon = |mut v: Vec<(u64, u32, Vec<EntityAddress>)>| {
                v.sort_unstable_by_key(|(k, _, _)| *k);
                v
            };
            let (want, got) =
                (canon(cf.export_entries()), canon(restored.export_entries()));
            if want != got {
                return Err(format!(
                    "filter state diverged: {} vs {} entries",
                    want.len(),
                    got.len()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn log_truncated_at_any_byte_replays_the_longest_valid_prefix() {
    forall(
        Config { cases: 200, ..Config::default() },
        |rng| {
            let ops = gen_ops(rng, 12);
            let total = encode_log(&ops).len();
            (ops, rng.below(total as u64 + 1) as usize)
        },
        |(ops, cut)| {
            let bytes = encode_log(ops);
            // the maximal prefix of records fully inside the cut
            let mut fit = 0usize;
            let mut off = 0usize;
            for op in ops {
                let next = off + op.encode().len();
                if next > *cut {
                    break;
                }
                off = next;
                fit += 1;
            }
            let replay = replay_bytes(&bytes[..*cut]).map_err(|e| {
                format!("byte-truncation must never refuse: {e}")
            })?;
            if replay.ops != ops[..fit] {
                return Err(format!(
                    "cut at {cut}: replayed {} ops, longest valid prefix \
                     is {fit}",
                    replay.ops.len()
                ));
            }
            if replay.valid_len != off as u64 {
                return Err(format!(
                    "cut at {cut}: valid_len {} != prefix end {off}",
                    replay.valid_len
                ));
            }
            let clean = off == *cut;
            match replay.tail {
                TailOutcome::Clean if !clean => {
                    Err(format!("cut at {cut} mid-record reported Clean"))
                }
                TailOutcome::Truncated { dropped_bytes }
                    if clean || dropped_bytes != (*cut - off) as u64 =>
                {
                    Err(format!(
                        "cut at {cut}: dropped {dropped_bytes}, expected {}",
                        *cut - off
                    ))
                }
                _ => Ok(()),
            }
        },
        |(ops, cut)| {
            // shrink the op list; clamp the cut into the smaller image
            shrink_vec(ops)
                .into_iter()
                .map(|o| {
                    let max = encode_log(&o).len();
                    (o, (*cut).min(max))
                })
                .collect()
        },
    );
}

#[test]
fn snapshot_with_any_single_bit_flipped_is_refused() {
    forall_simple(
        120,
        |rng| {
            let snap = gen_snapshot(rng);
            let bits = snap.to_bytes().len() * 8;
            (snap, rng.below(bits as u64) as usize)
        },
        |(snap, bit)| {
            let mut bytes = snap.to_bytes();
            bytes[bit / 8] ^= 1 << (bit % 8);
            match Snapshot::from_bytes(&bytes) {
                Err(_) => Ok(()), // refused loudly — required
                Ok(loaded) => Err(format!(
                    "bit {bit} flipped yet the snapshot loaded \
                     ({} entries, epoch {})",
                    loaded.entries.len(),
                    loaded.partition_epoch
                )),
            }
        },
    );
}

#[test]
fn log_with_a_flipped_bit_errs_or_yields_a_clean_prefix() {
    // A flipped bit inside a record body/CRC is detected: mid-log it
    // refuses loudly, on the final record it truncates (indistinct
    // from a torn tail). A flipped LENGTH field may also swallow valid
    // trailing records by overrunning EOF — still a prefix. What can
    // NEVER happen: fabricated, mutated or reordered operations.
    forall_simple(
        200,
        |rng| {
            let ops = gen_ops(rng, 10);
            let bits = encode_log(&ops).len() * 8;
            (ops, rng.below(bits as u64) as usize)
        },
        |(ops, bit)| {
            let mut bytes = encode_log(ops);
            bytes[bit / 8] ^= 1 << (bit % 8);
            match replay_bytes(&bytes) {
                Err(_) => Ok(()), // loud refusal
                Ok(replay) => {
                    if replay.ops.len() <= ops.len()
                        && replay.ops == ops[..replay.ops.len()]
                    {
                        Ok(())
                    } else {
                        Err(format!(
                            "bit {bit} flipped and replay returned {} ops \
                             that are NOT a prefix of the {} written",
                            replay.ops.len(),
                            ops.len()
                        ))
                    }
                }
            }
        },
    );
}
