"""Layer-1 Pallas kernels for CFT-RAG's neural compute.

Three kernels back the request-path artifacts:

* :mod:`similarity` — tiled query x corpus similarity matmul (vector search).
* :mod:`attention`  — single-head masked attention weights (fact re-ranking).
* :mod:`layernorm`  — fused layer-norm (embedder output head).

All kernels are lowered with ``interpret=True`` (the CPU PJRT plugin cannot
execute Mosaic custom-calls); their *structure* — BlockSpec tiling, VMEM
footprint, MXU-aligned tiles — is designed for TPU per DESIGN.md
§Hardware-Adaptation. Pure-jnp oracles live in :mod:`ref` and every kernel
is pytest/hypothesis-checked against them.
"""

from . import ref  # noqa: F401
from .similarity import similarity_scores  # noqa: F401
from .attention import attention_weights  # noqa: F401
from .layernorm import layer_norm  # noqa: F401
