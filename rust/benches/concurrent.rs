//! Concurrent retrieval bench: lookup throughput of the shard-parallel
//! Cuckoo retriever vs the old global-mutex design, across thread counts
//! — the scaling the coordinator's worker pool now inherits.
//!
//! Two arms per thread count:
//!
//! * `mutex`   — one `CuckooTRag` behind a `Mutex` (the pre-sharding
//!   coordinator design): every lookup serializes.
//! * `sharded` — `ShardedCuckooTRag`: lookups take only the read lock of
//!   the key's shard, so throughput scales with threads.
//!
//! Also reports single-thread lookup latency for the unsharded filter vs
//! the sharded one (the sharding overhead on an uncontended path), and —
//! the PR-2 scenario — **reader latency during shard expansion**:
//! readers time every `lookup_into` while a writer pushes the filter
//! through doubling migrations, once with monolithic migration
//! (`migration_step_buckets = 0`, the pre-PR-2 behavior: a reader can
//! stall behind a whole-table rebuild) and once with incremental
//! migration (every reader wait bounded by one small step).
//!
//! Two PR-3 scenarios ride along:
//!
//! * **Concurrent Bloom baseline** — the tree-Bloom annotations are
//!   read-only after build, so `ArcRetriever<BloomTRag>` shares them
//!   lock-free; measured against the old `MutexRetriever` funnel at 1
//!   and max threads (the honest-concurrent-baselines ROADMAP item).
//! * **Shard router scatter-gather** — real TCP backends (each a full
//!   coordinator) behind the `router/` subsystem, 1-backend vs
//!   N-backend aggregate throughput under the same client load. The
//!   single-backend arm is bottlenecked on its one serialized
//!   embed/search batcher; N backends run N batchers.
//!
//! And the PR-4 scenario: **R-way replicated partitioned serving** —
//! 3 key-partitioned backends under a *skewed* (Zipf) single-entity
//! mention load, R=1 vs R=2. R=1 pins every hot key to one backend;
//! R=2 lets the least-loaded-replica read path spread each hot key
//! over two backends, at 2× the per-key index memory — both axes
//! (throughput and per-backend index bytes) are reported.
//!
//! The PR-7 scenario: **connection scaling** — many idle connections
//! squatting while a hot minority exchanges request lines, served once
//! by the nonblocking reactor core (`reactor/server.rs`: one poll
//! thread for every connection) and once by the pre-reactor shape (one
//! OS thread per accepted connection). Reports how many concurrent
//! connections each design sustained plus hot-path p50/p99/max — the
//! reactor's idle connections cost bytes of state, the baseline's cost
//! a thread each.
//!
//! The PR-8 scenario: **observability overhead** — the same client load
//! against one TCP coordinator with request tracing disabled
//! (`trace_sample_every = 0`, the production default) and head-sampling
//! *every* query. The acceptance claim is that the disabled-sampling
//! tracing hooks plus the filter's relaxed-atomic telemetry counters
//! cost < 3% throughput.
//!
//! The ISSUE-10 scenario: **reply caching under a hot query mix** — a
//! skewed (Zipf s = 1.1) single-entity load repeated against a 3-backend
//! R=2 partitioned fleet, once with the reply cache disabled
//! (`cache_capacity_bytes = 0`, i.e. `--cache-off`) and once with the
//! default 8 MiB cache. The working set repeats every pass, so after
//! the first pass the cached arm answers hot queries from memory;
//! the arm reports the hit rate and the throughput delta vs the
//! uncached arm.
//!
//! Run: `cargo bench --bench concurrent`. Writes `results/concurrent.csv`,
//! `results/concurrent_expansion.csv`, `results/concurrent_bloom.csv`,
//! `results/concurrent_router.csv`, `results/concurrent_replication.csv`,
//! `results/concurrent_join.csv`, `results/concurrent_connscale.csv`,
//! `results/concurrent_obs.csv`, `results/concurrent_cache.csv`, and a
//! machine-readable summary of every scenario's headline numbers to
//! `results/BENCH_concurrent.json`.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use cft_rag::bench::experiments::experiment_forest;
use cft_rag::bench::harness::{bench, print_table};
use cft_rag::coordinator::tcp::{serve_listener, serve_with_shutdown};
use cft_rag::coordinator::{Coordinator, CoordinatorConfig};
use cft_rag::data::corpus::corpus_from_texts;
use cft_rag::data::hospital::{HospitalConfig, HospitalDataset};
use cft_rag::data::workload::{Workload, WorkloadConfig};
use cft_rag::filter::cuckoo::CuckooConfig;
use cft_rag::filter::sharded::ShardedCuckooFilter;
use cft_rag::forest::EntityAddress;
use cft_rag::rag::config::{KeyPartition, RagConfig, RouterConfig};
use cft_rag::reactor::server::{
    serve_lines, Completion, LineService, ServerConfig, ServerStats,
};
use cft_rag::retrieval::bloom_rag::BloomTRag;
use cft_rag::retrieval::cuckoo_rag::CuckooTRag;
use cft_rag::retrieval::sharded_rag::ShardedCuckooTRag;
use cft_rag::retrieval::{
    ArcRetriever, ConcurrentRetriever, MutexRetriever, Retriever,
};
use cft_rag::router::Router;
use cft_rag::runtime::engine::{Engine, NativeEngine};
use cft_rag::util::cli::{spec, Args};
use cft_rag::util::csv::CsvTable;
use cft_rag::util::json::Json;
use cft_rag::util::rng::{fnv1a, Rng};

fn main() {
    let args = Args::from_env(vec![
        spec("trees", "forest size", Some("300"), false),
        spec("threads", "comma-separated thread counts", Some("1,2,4,8"), false),
        spec("shards", "shard count (0 = one per core)", Some("0"), false),
        spec("lookups", "lookups per thread per repeat", Some("200000"), false),
        spec("repeats", "timed repeats", Some("5"), false),
        spec("out", "CSV output path", Some("results/concurrent.csv"), false),
        spec(
            "router-backends",
            "comma-separated backend counts for the router scenario",
            Some("1,4"),
            false,
        ),
        spec("router-queries", "queries per router arm", Some("384"), false),
        spec("router-clients", "concurrent router clients", Some("8"), false),
        spec("router-workers", "workers per routed backend", Some("2"), false),
        spec("router-trees", "forest size for the router scenario", Some("60"), false),
        spec(
            "connscale-idle",
            "idle squatter connections for the connection-scaling arm",
            Some("10000"),
            false,
        ),
        spec(
            "connscale-hot",
            "hot request-exchanging connections for the scaling arm",
            Some("1000"),
            false,
        ),
        spec(
            "connscale-passes",
            "request roundtrips per hot connection",
            Some("3"),
            false,
        ),
        spec("bench", "ignored (cargo bench passes it)", None, true),
    ])
    .unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    if args.wants_help() {
        println!("{}", args.usage());
        return;
    }
    let trees: usize = args.num_or("trees", 300);
    let thread_counts: Vec<usize> = args.list_or("threads", &[1, 2, 4, 8]);
    let lookups: usize = args.num_or("lookups", 200_000);
    let repeats: usize = args.num_or("repeats", 5);
    let shards = match args.num_or("shards", 0usize) {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        n => n,
    };

    let forest = experiment_forest(trees, 42);
    // Every entity name, repeated in random order per thread, so lookups
    // hit (the serving-path case) and spread across all shards.
    let names: Vec<String> = forest
        .interner()
        .iter()
        .map(|(_, n)| n.to_string())
        .collect();
    assert!(!names.is_empty());

    let mutexed = Arc::new(Mutex::new(CuckooTRag::new(forest.clone())));
    let sharded = Arc::new(ShardedCuckooTRag::new(forest.clone(), shards));
    println!(
        "forest: {trees} trees, {} entities; {} shards; {lookups} lookups/thread",
        names.len(),
        sharded.filter().num_shards()
    );

    let mut rows = Vec::new();
    let mut csv = CsvTable::new(&["design", "threads", "mops_per_s", "speedup_vs_mutex"]);
    let mut sweep_json: Vec<Json> = Vec::new();

    // per-(arm, threads) p50 Mops/s
    let run = |label: &str, threads: usize, f: &(dyn Fn(usize) + Sync)| -> f64 {
        let r = bench(label, 1, repeats, || {
            std::thread::scope(|s| {
                for t in 0..threads {
                    s.spawn(move || f(t));
                }
            });
        });
        (threads * lookups) as f64 / r.summary().p50 / 1e6
    };

    for &threads in &thread_counts {
        let mutex_arm = {
            let m = mutexed.clone();
            let names = &names;
            run("mutex", threads, &move |tid: usize| {
                let mut rng = Rng::new(0xBEEF ^ tid as u64);
                let mut out = Vec::with_capacity(64);
                let mut found = 0usize;
                for _ in 0..lookups {
                    let name = &names[rng.range(0, names.len())];
                    out.clear();
                    m.lock().unwrap().find_into(name, &mut out);
                    if !out.is_empty() {
                        found += 1;
                    }
                }
                assert!(found > 0);
            })
        };
        let sharded_arm = {
            let r = sharded.clone();
            let names = &names;
            run("sharded", threads, &move |tid: usize| {
                let mut rng = Rng::new(0xBEEF ^ tid as u64);
                let mut out = Vec::with_capacity(64);
                let mut found = 0usize;
                for _ in 0..lookups {
                    let name = &names[rng.range(0, names.len())];
                    out.clear();
                    r.find_concurrent(name, &mut out);
                    if !out.is_empty() {
                        found += 1;
                    }
                }
                assert!(found > 0);
            })
        };
        for (design, mops) in [("mutex", mutex_arm), ("sharded", sharded_arm)] {
            let speedup = mops / mutex_arm;
            rows.push(vec![
                design.to_string(),
                threads.to_string(),
                format!("{mops:.2}"),
                format!("{speedup:.2}x"),
            ]);
            csv.push(&[
                design.to_string(),
                threads.to_string(),
                format!("{mops}"),
                format!("{speedup}"),
            ]);
            sweep_json.push(Json::obj(vec![
                ("design", Json::Str(design.to_string())),
                ("threads", Json::Num(threads as f64)),
                ("mops_per_s", Json::Num(mops)),
                ("speedup_vs_mutex", Json::Num(speedup)),
            ]));
        }
    }

    print_table(
        "Concurrent retrieval throughput (lookups, all threads hammering)",
        &["design", "threads", "Mops/s", "vs mutex"],
        &rows,
    );

    // single-thread latency sanity: sharding must cost ~nothing uncontended
    let mut plain = CuckooTRag::new(forest.clone());
    let single_plain = bench("plain-1t", 1, repeats, || {
        let mut rng = Rng::new(7);
        let mut out = Vec::with_capacity(64);
        for _ in 0..lookups {
            out.clear();
            plain.find_into(&names[rng.range(0, names.len())], &mut out);
        }
    });
    let single_sharded = bench("sharded-1t", 1, repeats, || {
        let mut rng = Rng::new(7);
        let mut out = Vec::with_capacity(64);
        for _ in 0..lookups {
            out.clear();
            sharded.find_concurrent(&names[rng.range(0, names.len())], &mut out);
        }
    });
    let p = single_plain.summary().p50 / lookups as f64 * 1e9;
    let s = single_sharded.summary().p50 / lookups as f64 * 1e9;
    println!(
        "\nsingle-thread lookup: unsharded {p:.1} ns, sharded {s:.1} ns ({:.0}% overhead)",
        (s / p - 1.0) * 100.0
    );

    let out = args.str_or("out", "results/concurrent.csv");
    csv.write_to(&out).expect("write csv");
    println!("\nwrote {out}");

    // ---- reader tail latency during shard expansion (PR-2 scenario) ----
    // Preload each arm to ~90% of the load threshold, then let a writer
    // push every shard through a doubling while 4 reader threads time
    // each individual lookup. The acceptance claim: with incremental
    // migration no lookup_into ever waits behind a full-table migration
    // — its worst case is one bounded step — where the monolithic arm's
    // tail is the whole rebuild.
    println!("\nreader latency during shard expansion (4 readers, 2 shards):");
    let mut exp_csv = CsvTable::new(&[
        "migration",
        "p50_ns",
        "p99_ns",
        "max_us",
        "lookups",
        "expansions",
    ]);
    let mut exp_json: Vec<Json> = Vec::new();
    let exp_key = |i: u64| fnv1a(&i.to_le_bytes());
    for (label, step) in [("monolithic", 0usize), ("incremental", 64)] {
        let cf = Arc::new(ShardedCuckooFilter::new(
            CuckooConfig {
                initial_buckets: 1 << 14,
                migration_step_buckets: step,
                ..CuckooConfig::default()
            },
            2,
        ));
        let preload = (cf.capacity_slots() as f64 * 0.90) as u64;
        for i in 0..preload {
            let _ = cf.insert(exp_key(i), &[EntityAddress::new(i as u32, 0)]);
        }
        let stop = AtomicBool::new(false);
        let per_thread: Vec<Vec<u64>> = std::thread::scope(|s| {
            let readers: Vec<_> = (0..4u64)
                .map(|t| {
                    let cf = &cf;
                    let stop = &stop;
                    s.spawn(move || {
                        let mut rng = Rng::new(0xA11C_E5ED ^ t);
                        let mut out = Vec::with_capacity(4);
                        let mut lat = Vec::with_capacity(1 << 18);
                        while !stop.load(Ordering::Relaxed) {
                            let k = exp_key(rng.below(preload));
                            out.clear();
                            let t0 = Instant::now();
                            cf.lookup_into(k, &mut out);
                            lat.push(t0.elapsed().as_nanos() as u64);
                        }
                        lat
                    })
                })
                .collect();
            // writer: +40% of capacity forces ≥1 doubling per shard
            let extra = (cf.capacity_slots() as f64 * 0.40) as u64;
            for i in 0..extra {
                let _ = cf
                    .insert(exp_key(preload + i), &[EntityAddress::new(0, 0)]);
            }
            stop.store(true, Ordering::Relaxed);
            readers.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let mut lat: Vec<u64> = per_thread.into_iter().flatten().collect();
        lat.sort_unstable();
        let pick = |q: f64| lat[((lat.len() - 1) as f64 * q) as usize];
        let (p50, p99) = (pick(0.50), pick(0.99));
        let max_us = *lat.last().unwrap() as f64 / 1000.0;
        let expansions = cf.stats().expansions;
        println!(
            "  {label:<12} p50 {p50:>6} ns   p99 {p99:>8} ns   \
             max {max_us:>10.1} us   ({} lookups, {expansions} expansions)",
            lat.len(),
        );
        exp_csv.push(&[
            label.to_string(),
            p50.to_string(),
            p99.to_string(),
            format!("{max_us}"),
            lat.len().to_string(),
            expansions.to_string(),
        ]);
        exp_json.push(Json::obj(vec![
            ("migration", Json::Str(label.to_string())),
            ("p50_ns", Json::Num(p50 as f64)),
            ("p99_ns", Json::Num(p99 as f64)),
            ("max_us", Json::Num(max_us)),
            ("expansions", Json::Num(expansions as f64)),
        ]));
    }
    // derive from `out` without clobbering it when --out lacks ".csv"
    let exp_out = match out.strip_suffix(".csv") {
        Some(stem) => format!("{stem}_expansion.csv"),
        None => format!("{out}_expansion.csv"),
    };
    exp_csv.write_to(&exp_out).expect("write expansion csv");
    println!("wrote {exp_out}");

    // ---- concurrent Bloom baseline: ArcRetriever vs MutexRetriever ----
    // The tree-Bloom annotations are immutable after build; sharing them
    // as Arcs must scale with reader threads where the mutex funnel
    // cannot. Fewer lookups than the CF arms: a Bloom lookup walks trees.
    let bloom_threads = *thread_counts.iter().max().unwrap_or(&4);
    let bloom_lookups = (lookups / 10).max(1_000);
    println!(
        "\nconcurrent Bloom baseline ({bloom_lookups} lookups/thread, \
         1 vs {bloom_threads} threads):"
    );
    let bloom_mutex: Arc<dyn ConcurrentRetriever> = Arc::new(
        MutexRetriever::new(Box::new(BloomTRag::new(forest.clone(), 0.01))),
    );
    let bloom_arc: Arc<dyn ConcurrentRetriever> =
        Arc::new(ArcRetriever::new(BloomTRag::new(forest.clone(), 0.01)));
    let mut bloom_csv =
        CsvTable::new(&["design", "threads", "mops_per_s", "scaling"]);
    let mut bloom_json: Vec<Json> = Vec::new();
    for (label, r) in [("bloom-mutex", &bloom_mutex), ("bloom-arc", &bloom_arc)]
    {
        let mut one_thread = 0.0f64;
        for threads in [1usize, bloom_threads] {
            let result = bench(label, 1, repeats, || {
                std::thread::scope(|s| {
                    for t in 0..threads {
                        let r = r.clone();
                        let names = &names;
                        s.spawn(move || {
                            let mut rng = Rng::new(0xB100 ^ t as u64);
                            let mut out = Vec::with_capacity(64);
                            for _ in 0..bloom_lookups {
                                let name = &names[rng.range(0, names.len())];
                                out.clear();
                                r.find_concurrent(name, &mut out);
                            }
                        });
                    }
                });
            });
            let mops = (threads * bloom_lookups) as f64
                / result.summary().p50
                / 1e6;
            if threads == 1 {
                one_thread = mops;
            }
            let scaling = mops / one_thread;
            println!(
                "  {label:<12} {threads:>2} threads  {mops:>7.3} Mops/s  \
                 ({scaling:.2}x vs 1 thread)"
            );
            bloom_csv.push(&[
                label.to_string(),
                threads.to_string(),
                format!("{mops}"),
                format!("{scaling}"),
            ]);
            bloom_json.push(Json::obj(vec![
                ("design", Json::Str(label.to_string())),
                ("threads", Json::Num(threads as f64)),
                ("mops_per_s", Json::Num(mops)),
                ("scaling", Json::Num(scaling)),
            ]));
        }
    }
    let bloom_out = match out.strip_suffix(".csv") {
        Some(stem) => format!("{stem}_bloom.csv"),
        None => format!("{out}_bloom.csv"),
    };
    bloom_csv.write_to(&bloom_out).expect("write bloom csv");
    println!("wrote {bloom_out}");

    // ---- shard router: 1-backend vs N-backend scatter-gather ----
    let router_json = router_scenario(&args, &out);

    // ---- replication: R=1 vs R=2 partitioned backends, skewed load ----
    let replication_json = replication_scenario(&args, &out);

    // ---- elasticity: join a backend into a live R=2 fleet ----
    let join_json = join_scenario(&args, &out);

    // ---- connection scaling: reactor vs thread-per-connection ----
    let connscale_json = connscale_scenario(&args, &out);

    // ---- observability overhead: tracing off vs every-query ----
    let obs_json = obs_overhead_scenario(&args, &out);

    // ---- reply cache: hot Zipf load, cache off vs the 8 MiB default ----
    let cache_json = cache_scenario(&args, &out);

    // machine-readable summary of every scenario, alongside the CSVs
    let bench_json = Json::obj(vec![
        ("bench", Json::Str("concurrent".to_string())),
        ("throughput_sweep", Json::Arr(sweep_json)),
        (
            "single_thread_lookup_ns",
            Json::obj(vec![
                ("unsharded", Json::Num(p)),
                ("sharded", Json::Num(s)),
            ]),
        ),
        ("expansion", Json::Arr(exp_json)),
        ("bloom", Json::Arr(bloom_json)),
        ("router", router_json),
        ("replication", replication_json),
        ("join", join_json),
        ("connscale", connscale_json),
        ("obs_overhead", obs_json),
        ("reply_cache", cache_json),
    ]);
    let json_out = match out.rfind('/') {
        Some(i) => format!("{}/BENCH_concurrent.json", &out[..i]),
        None => "BENCH_concurrent.json".to_string(),
    };
    std::fs::write(&json_out, format!("{bench_json}\n"))
        .expect("write bench json");
    println!("wrote {json_out}");
}

/// The PR-3 acceptance scenario: the same client load against the
/// router fronting 1 backend and N backends (real TCP coordinators,
/// each with its own engine and its own serialized embed/search
/// batcher), reporting aggregate throughput and the speedup of the
/// N-backend arm over the single-backend arm.
fn router_scenario(args: &Args, out: &str) -> Json {
    let arms: Vec<usize> = args.list_or("router-backends", &[1, 4]);
    let queries: usize = args.num_or("router-queries", 384);
    let clients: usize = args.num_or("router-clients", 8).max(1);
    let workers: usize = args.num_or("router-workers", 2);
    let trees: usize = args.num_or("router-trees", 60);

    let ds = HospitalDataset::generate(HospitalConfig {
        trees,
        ..HospitalConfig::default()
    });
    let forest = Arc::new(ds.build_forest());
    let names: Vec<String> = forest
        .interner()
        .iter()
        .map(|(_, n)| n.to_string())
        .collect();
    // Single-entity, uniformly drawn queries: each query has exactly one
    // owner, so the load spreads across backends by key ownership — the
    // scaling this scenario measures. (A fanned-out multi-entity query
    // pays the per-line embed/search fixed cost once *per owner*, which
    // measures merge overhead, not scale-out; the integration tests and
    // `serve_requests --router N` cover that path.)
    let workload = Workload::generate(
        &forest,
        WorkloadConfig {
            entities_per_query: 1,
            queries: 64,
            zipf_s: 0.0,
            deep_bias: 0.0,
            ..Default::default()
        },
    );

    println!(
        "\nshard router scatter-gather ({queries} queries, {clients} clients, \
         {workers} workers/backend, {trees} trees):"
    );
    let mut csv = CsvTable::new(&[
        "backends",
        "clients",
        "queries",
        "wall_s",
        "qps",
        "speedup_vs_1",
        "fanouts",
        "failures",
    ]);
    let mut arms_json: Vec<Json> = Vec::new();
    let mut base_qps = 0.0f64;
    for &n in &arms {
        // real TCP backends, each a full coordinator with its own engine
        let mut backends = Vec::with_capacity(n);
        for _ in 0..n.max(1) {
            let engine: Arc<dyn Engine> = Arc::new(NativeEngine::new());
            let coordinator = Arc::new(
                Coordinator::start(
                    forest.clone(),
                    corpus_from_texts(&ds.documents()),
                    engine,
                    RagConfig::default(),
                    CoordinatorConfig { workers, ..Default::default() },
                )
                .expect("backend coordinator"),
            );
            let handle = serve_with_shutdown(coordinator.clone(), "127.0.0.1:0")
                .expect("backend listener");
            backends.push((coordinator, handle));
        }
        let addrs: Vec<String> =
            backends.iter().map(|(_, h)| h.addr().to_string()).collect();
        let router = Arc::new(
            Router::connect(
                names.iter().map(String::as_str),
                &RouterConfig::for_backends(addrs),
            )
            .expect("router"),
        );

        // warmup: touch every backend's pools and caches
        for q in workload.queries.iter().take(8) {
            let _ = router.query(&q.text);
        }

        let t0 = Instant::now();
        let failures: usize = std::thread::scope(|s| {
            let handles: Vec<_> = (0..clients)
                .map(|c| {
                    let router = router.clone();
                    let workload = &workload;
                    let share = queries / clients
                        + usize::from(c < queries % clients);
                    s.spawn(move || {
                        let mut failures = 0usize;
                        for i in 0..share {
                            let q = &workload.queries
                                [(c + i * clients) % workload.queries.len()];
                            let reply = router.query(&q.text);
                            if reply.get("ok") != Some(&Json::Bool(true)) {
                                failures += 1;
                            }
                        }
                        failures
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).sum()
        });
        let wall = t0.elapsed().as_secs_f64();
        let qps = queries as f64 / wall;
        if base_qps == 0.0 {
            base_qps = qps;
        }
        let speedup = qps / base_qps;
        let snap = router.snapshot();
        println!(
            "  {n:>2} backends  {qps:>8.1} q/s  ({speedup:.2}x vs {} backend)  \
             wall {wall:.2}s  {} fanouts  {failures} failures",
            arms[0], snap.fanouts,
        );
        csv.push(&[
            n.to_string(),
            clients.to_string(),
            queries.to_string(),
            format!("{wall}"),
            format!("{qps}"),
            format!("{speedup}"),
            snap.fanouts.to_string(),
            failures.to_string(),
        ]);
        arms_json.push(Json::obj(vec![
            ("backends", Json::Num(n as f64)),
            ("qps", Json::Num(qps)),
            ("speedup_vs_1", Json::Num(speedup)),
            ("fanouts", Json::Num(snap.fanouts as f64)),
            ("failures", Json::Num(failures as f64)),
        ]));

        drop(router); // prober stops before its backends vanish
        for (coordinator, handle) in backends {
            handle.shutdown();
            coordinator.stop();
        }
    }
    let router_out = match out.strip_suffix(".csv") {
        Some(stem) => format!("{stem}_router.csv"),
        None => format!("{out}_router.csv"),
    };
    csv.write_to(&router_out).expect("write router csv");
    println!("wrote {router_out}");
    Json::obj(vec![
        ("arms", Json::Arr(arms_json)),
        ("csv", Json::Str(router_out)),
    ])
}

/// The ISSUE-4 acceptance scenario: 3 key-partitioned backends under a
/// skewed (Zipf) single-entity mention load, once with R=1 (every key
/// pinned to one backend — hot keys hammer their owner) and once with
/// R=2 (the least-loaded-replica read path spreads each hot key over
/// two backends). Reports aggregate throughput *and* per-backend index
/// memory — replication buys read capacity at exactly R× the per-key
/// index bytes, and this arm makes both sides of that trade visible.
fn replication_scenario(args: &Args, out: &str) -> Json {
    let queries: usize = args.num_or("router-queries", 384);
    let clients: usize = args.num_or("router-clients", 8).max(1);
    let workers: usize = args.num_or("router-workers", 2);
    let trees: usize = args.num_or("router-trees", 60);
    const N: usize = 3;

    let ds = HospitalDataset::generate(HospitalConfig {
        trees,
        ..HospitalConfig::default()
    });
    let forest = Arc::new(ds.build_forest());
    let names: Vec<String> = forest
        .interner()
        .iter()
        .map(|(_, n)| n.to_string())
        .collect();
    // Skewed single-entity mentions: Zipf-drawn, so a handful of hot
    // keys dominate — the load shape replica-spreading exists for.
    let workload = Workload::generate(
        &forest,
        WorkloadConfig {
            entities_per_query: 1,
            queries: 64,
            zipf_s: 1.2,
            deep_bias: 0.0,
            ..Default::default()
        },
    );

    println!(
        "\nreplicated partitioned serving ({N} backends, Zipf mention \
         load, {queries} queries, {clients} clients):"
    );
    let mut csv = CsvTable::new(&[
        "replicas",
        "qps",
        "speedup_vs_r1",
        "replica_hits",
        "failovers",
        "degraded",
        "failures",
        "index_kib_mean_per_backend",
        "index_kib_total",
    ]);
    let mut arms_json: Vec<Json> = Vec::new();
    let mut base_qps = 0.0f64;
    for r in [1usize, 2] {
        // bind first: partitioned indexes need the final address list
        let listeners: Vec<TcpListener> = (0..N)
            .map(|_| TcpListener::bind("127.0.0.1:0").expect("bind"))
            .collect();
        let addrs: Vec<String> = listeners
            .iter()
            .map(|l| l.local_addr().unwrap().to_string())
            .collect();
        let mut backends = Vec::with_capacity(N);
        for (i, listener) in listeners.into_iter().enumerate() {
            let engine: Arc<dyn Engine> = Arc::new(NativeEngine::new());
            let cfg = RagConfig {
                replication_factor: r,
                key_partition: Some(
                    KeyPartition::new(addrs.clone(), i, r)
                        .expect("partition"),
                ),
                ..RagConfig::default()
            };
            let coordinator = Arc::new(
                Coordinator::start(
                    forest.clone(),
                    corpus_from_texts(&ds.documents()),
                    engine,
                    cfg,
                    CoordinatorConfig { workers, ..Default::default() },
                )
                .expect("backend coordinator"),
            );
            let handle = serve_listener(coordinator.clone(), listener)
                .expect("backend listener");
            backends.push((coordinator, handle));
        }
        let router = Arc::new(
            Router::connect(
                names.iter().map(String::as_str),
                &RouterConfig {
                    replication_factor: r,
                    // fast probe cadence so the least-loaded gauge
                    // tracks the skew within the short bench window
                    probe_interval: Duration::from_millis(25),
                    ..RouterConfig::for_backends(addrs)
                },
            )
            .expect("router"),
        );

        for q in workload.queries.iter().take(8) {
            let _ = router.query(&q.text);
        }

        let t0 = Instant::now();
        let failures: usize = std::thread::scope(|s| {
            let handles: Vec<_> = (0..clients)
                .map(|c| {
                    let router = router.clone();
                    let workload = &workload;
                    let share = queries / clients
                        + usize::from(c < queries % clients);
                    s.spawn(move || {
                        let mut failures = 0usize;
                        for i in 0..share {
                            let q = &workload.queries
                                [(c + i * clients) % workload.queries.len()];
                            let reply = router.query(&q.text);
                            if reply.get("ok") != Some(&Json::Bool(true)) {
                                failures += 1;
                            }
                        }
                        failures
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).sum()
        });
        let wall = t0.elapsed().as_secs_f64();
        let qps = queries as f64 / wall;
        if base_qps == 0.0 {
            base_qps = qps;
        }
        let speedup = qps / base_qps;
        let snap = router.snapshot();
        let per_backend: Vec<f64> = backends
            .iter()
            .map(|(c, _)| c.index_bytes() as f64 / 1024.0)
            .collect();
        let total_kib: f64 = per_backend.iter().sum();
        let mean_kib = total_kib / N as f64;
        println!(
            "  R={r}  {qps:>8.1} q/s ({speedup:.2}x vs R=1)  \
             {} replica hits  {} failovers  {} degraded  {failures} \
             failures  index {mean_kib:.1} KiB/backend ({total_kib:.1} \
             KiB fleet)",
            snap.replica_hits, snap.failovers, snap.degraded,
        );
        csv.push(&[
            r.to_string(),
            format!("{qps}"),
            format!("{speedup}"),
            snap.replica_hits.to_string(),
            snap.failovers.to_string(),
            snap.degraded.to_string(),
            failures.to_string(),
            format!("{mean_kib}"),
            format!("{total_kib}"),
        ]);
        arms_json.push(Json::obj(vec![
            ("replicas", Json::Num(r as f64)),
            ("qps", Json::Num(qps)),
            ("speedup_vs_r1", Json::Num(speedup)),
            ("replica_hits", Json::Num(snap.replica_hits as f64)),
            ("failovers", Json::Num(snap.failovers as f64)),
            ("degraded", Json::Num(snap.degraded as f64)),
            ("failures", Json::Num(failures as f64)),
            ("index_kib_mean_per_backend", Json::Num(mean_kib)),
        ]));

        drop(router); // prober stops before its backends vanish
        for (coordinator, handle) in backends {
            handle.shutdown();
            coordinator.stop();
        }
    }
    let rep_out = match out.strip_suffix(".csv") {
        Some(stem) => format!("{stem}_replication.csv"),
        None => format!("{out}_replication.csv"),
    };
    csv.write_to(&rep_out).expect("write replication csv");
    println!("wrote {rep_out}");
    Json::obj(vec![
        ("arms", Json::Arr(arms_json)),
        ("csv", Json::Str(rep_out)),
    ])
}

/// The ISSUE-10 acceptance scenario: the reply cache under a hot query
/// mix. A skewed (Zipf s = 1.1) single-entity load cycles through a
/// 64-query working set against a 3-backend R=2 partitioned fleet,
/// once with the cache disabled (`cache_capacity_bytes = 0` — what
/// `--cache-off` sets) and once with the 8 MiB default. Every pass
/// after the first re-asks the same hot queries, so the cached arm
/// serves most of them from memory without touching a backend.
/// Reports the cached arm's hit rate and its throughput delta vs the
/// uncached arm — the two headline numbers of the caching PR.
fn cache_scenario(args: &Args, out: &str) -> Json {
    let queries: usize = args.num_or("router-queries", 384);
    let clients: usize = args.num_or("router-clients", 8).max(1);
    let workers: usize = args.num_or("router-workers", 2);
    let trees: usize = args.num_or("router-trees", 60);
    const N: usize = 3;
    const R: usize = 2;

    let ds = HospitalDataset::generate(HospitalConfig {
        trees,
        ..HospitalConfig::default()
    });
    let forest = Arc::new(ds.build_forest());
    let names: Vec<String> = forest
        .interner()
        .iter()
        .map(|(_, n)| n.to_string())
        .collect();
    // Hot working set: Zipf-drawn single-entity mentions, repeated every
    // pass — the load shape a reply cache exists for. s = 1.1 keeps a
    // long tail alive so misses never disappear entirely.
    let workload = Workload::generate(
        &forest,
        WorkloadConfig {
            entities_per_query: 1,
            queries: 64,
            zipf_s: 1.1,
            deep_bias: 0.0,
            ..Default::default()
        },
    );

    println!(
        "\nreply cache under hot Zipf load ({N} backends, R={R}, \
         {queries} queries, {clients} clients):"
    );
    let mut csv = CsvTable::new(&[
        "cache_bytes",
        "qps",
        "speedup_vs_off",
        "hits",
        "misses",
        "hit_rate",
        "evictions",
        "resident_bytes",
        "failures",
    ]);
    let mut arms_json: Vec<Json> = Vec::new();
    let mut base_qps = 0.0f64;
    for cache_bytes in [0usize, 8 * 1024 * 1024] {
        // bind first: partitioned indexes need the final address list
        let listeners: Vec<TcpListener> = (0..N)
            .map(|_| TcpListener::bind("127.0.0.1:0").expect("bind"))
            .collect();
        let addrs: Vec<String> = listeners
            .iter()
            .map(|l| l.local_addr().unwrap().to_string())
            .collect();
        let mut backends = Vec::with_capacity(N);
        for (i, listener) in listeners.into_iter().enumerate() {
            let engine: Arc<dyn Engine> = Arc::new(NativeEngine::new());
            let cfg = RagConfig {
                replication_factor: R,
                key_partition: Some(
                    KeyPartition::new(addrs.clone(), i, R)
                        .expect("partition"),
                ),
                ..RagConfig::default()
            };
            let coordinator = Arc::new(
                Coordinator::start(
                    forest.clone(),
                    corpus_from_texts(&ds.documents()),
                    engine,
                    cfg,
                    CoordinatorConfig { workers, ..Default::default() },
                )
                .expect("backend coordinator"),
            );
            let handle = serve_listener(coordinator.clone(), listener)
                .expect("backend listener");
            backends.push((coordinator, handle));
        }
        let router = Arc::new(
            Router::connect(
                names.iter().map(String::as_str),
                &RouterConfig {
                    replication_factor: R,
                    cache_capacity_bytes: cache_bytes,
                    probe_interval: Duration::from_millis(25),
                    ..RouterConfig::for_backends(addrs)
                },
            )
            .expect("router"),
        );

        for q in workload.queries.iter().take(8) {
            let _ = router.query(&q.text);
        }
        // counters are cumulative; delta out the warmup's fills so the
        // reported hit rate covers only the timed window
        let warm = router.snapshot();

        let t0 = Instant::now();
        let failures: usize = std::thread::scope(|s| {
            let handles: Vec<_> = (0..clients)
                .map(|c| {
                    let router = router.clone();
                    let workload = &workload;
                    let share = queries / clients
                        + usize::from(c < queries % clients);
                    s.spawn(move || {
                        let mut failures = 0usize;
                        for i in 0..share {
                            let q = &workload.queries
                                [(c + i * clients) % workload.queries.len()];
                            let reply = router.query(&q.text);
                            if reply.get("ok") != Some(&Json::Bool(true)) {
                                failures += 1;
                            }
                        }
                        failures
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).sum()
        });
        let wall = t0.elapsed().as_secs_f64();
        let qps = queries as f64 / wall;
        if base_qps == 0.0 {
            base_qps = qps;
        }
        let speedup = qps / base_qps;
        let snap = router.snapshot();
        let hits = snap.cache_hits - warm.cache_hits;
        let misses = snap.cache_misses - warm.cache_misses;
        let looked = (hits + misses).max(1);
        let hit_rate = hits as f64 / looked as f64;
        println!(
            "  cache {:>8}  {qps:>8.1} q/s ({speedup:.2}x vs off)  \
             {hits} hits / {misses} misses ({:.0}% hit rate)  \
             {} evictions  {} resident bytes  {failures} failures",
            if cache_bytes == 0 {
                "off".to_string()
            } else {
                format!("{} MiB", cache_bytes >> 20)
            },
            hit_rate * 100.0,
            snap.cache_evictions,
            snap.cache_bytes,
        );
        csv.push(&[
            cache_bytes.to_string(),
            format!("{qps}"),
            format!("{speedup}"),
            hits.to_string(),
            misses.to_string(),
            format!("{hit_rate}"),
            snap.cache_evictions.to_string(),
            snap.cache_bytes.to_string(),
            failures.to_string(),
        ]);
        arms_json.push(Json::obj(vec![
            ("cache_bytes", Json::Num(cache_bytes as f64)),
            ("qps", Json::Num(qps)),
            ("speedup_vs_off", Json::Num(speedup)),
            ("hits", Json::Num(hits as f64)),
            ("misses", Json::Num(misses as f64)),
            ("hit_rate", Json::Num(hit_rate)),
            ("evictions", Json::Num(snap.cache_evictions as f64)),
            ("resident_bytes", Json::Num(snap.cache_bytes as f64)),
            ("failures", Json::Num(failures as f64)),
        ]));

        drop(router); // prober stops before its backends vanish
        for (coordinator, handle) in backends {
            handle.shutdown();
            coordinator.stop();
        }
    }
    let cache_out = match out.strip_suffix(".csv") {
        Some(stem) => format!("{stem}_cache.csv"),
        None => format!("{out}_cache.csv"),
    };
    csv.write_to(&cache_out).expect("write cache csv");
    println!("wrote {cache_out}");
    Json::obj(vec![
        ("arms", Json::Arr(arms_json)),
        ("csv", Json::Str(cache_out)),
    ])
}

/// The ISSUE-5 acceptance scenario: a 4th backend joins a LIVE 3-node
/// key-partitioned R=2 fleet under Zipf load. Three phases of the same
/// client load — before the join, concurrent with the warm-up + epoch
/// roll + admission, and after — plus the memory axis: the joiner
/// starts with an EMPTY index (warming partition; every key it serves
/// arrives via the `\x01insert` handoff), and the incumbents' post-drop
/// live index shrinks from ~R/N toward the ~R/(N+1) bound.
fn join_scenario(args: &Args, out: &str) -> Json {
    let queries: usize = args.num_or("router-queries", 384);
    let clients: usize = args.num_or("router-clients", 8).max(1);
    let workers: usize = args.num_or("router-workers", 2);
    let trees: usize = args.num_or("router-trees", 60);
    const N: usize = 3;
    const R: usize = 2;

    let ds = HospitalDataset::generate(HospitalConfig {
        trees,
        ..HospitalConfig::default()
    });
    let forest = Arc::new(ds.build_forest());
    let names: Vec<String> = forest
        .interner()
        .iter()
        .map(|(_, n)| n.to_string())
        .collect();
    let workload = Workload::generate(
        &forest,
        WorkloadConfig {
            entities_per_query: 1,
            queries: 64,
            zipf_s: 1.2,
            deep_bias: 0.0,
            ..Default::default()
        },
    );

    // the full fleet's addresses are fixed up front (partitions hash
    // the address list): the first N serve now, the last one joins
    let listeners: Vec<TcpListener> = (0..N + 1)
        .map(|_| TcpListener::bind("127.0.0.1:0").expect("bind"))
        .collect();
    let all_addrs: Vec<String> = listeners
        .iter()
        .map(|l| l.local_addr().unwrap().to_string())
        .collect();
    let old_addrs: Vec<String> = all_addrs[..N].to_vec();

    let mut backends = Vec::with_capacity(N + 1);
    let mut listeners = listeners.into_iter();
    for (i, listener) in listeners.by_ref().take(N).enumerate() {
        let engine: Arc<dyn Engine> = Arc::new(NativeEngine::new());
        let cfg = RagConfig {
            replication_factor: R,
            key_partition: Some(
                KeyPartition::new(old_addrs.clone(), i, R).expect("partition"),
            ),
            ..RagConfig::default()
        };
        let coordinator = Arc::new(
            Coordinator::start(
                forest.clone(),
                corpus_from_texts(&ds.documents()),
                engine,
                cfg,
                CoordinatorConfig { workers, ..Default::default() },
            )
            .expect("backend coordinator"),
        );
        let handle =
            serve_listener(coordinator.clone(), listener).expect("listener");
        backends.push((coordinator, handle));
    }
    let router = Arc::new(
        Router::connect(
            names.iter().map(String::as_str),
            &RouterConfig {
                replication_factor: R,
                probe_interval: Duration::from_millis(25),
                ..RouterConfig::for_backends(old_addrs)
            },
        )
        .expect("router"),
    );
    for q in workload.queries.iter().take(8) {
        let _ = router.query(&q.text);
    }

    let run_load = |label: &str| -> (f64, usize) {
        let t0 = Instant::now();
        let failures: usize = std::thread::scope(|s| {
            let handles: Vec<_> = (0..clients)
                .map(|c| {
                    let router = router.clone();
                    let workload = &workload;
                    let share =
                        queries / clients + usize::from(c < queries % clients);
                    s.spawn(move || {
                        let mut failures = 0usize;
                        for i in 0..share {
                            let q = &workload.queries
                                [(c + i * clients) % workload.queries.len()];
                            let reply = router.query(&q.text);
                            if reply.get("ok") != Some(&Json::Bool(true)) {
                                failures += 1;
                            }
                        }
                        failures
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).sum()
        });
        let qps = queries as f64 / t0.elapsed().as_secs_f64();
        let _ = label;
        (qps, failures)
    };

    println!(
        "\nelastic join under Zipf load ({N}+1 backends, R={R}, \
         {queries} queries/phase, {clients} clients):"
    );
    let incumbent_kib = |backends: &[(Arc<Coordinator>, _)]| -> f64 {
        backends[..N]
            .iter()
            .map(|(c, _)| c.live_index_bytes() as f64 / 1024.0)
            .sum::<f64>()
            / N as f64
    };
    let kib_before = incumbent_kib(&backends);
    let (qps_before, fail_before) = run_load("before");

    // the joiner: EMPTY index (warming partition over the full list),
    // filled exclusively by the router's warm-up handoff
    let joiner_listener = listeners.next().expect("joiner listener");
    let engine: Arc<dyn Engine> = Arc::new(NativeEngine::new());
    let cfg = RagConfig {
        replication_factor: R,
        key_partition: Some(
            KeyPartition::joining(all_addrs.clone(), N, R)
                .expect("joining partition"),
        ),
        ..RagConfig::default()
    };
    let coordinator = Arc::new(
        Coordinator::start(
            forest.clone(),
            corpus_from_texts(&ds.documents()),
            engine,
            cfg,
            CoordinatorConfig { workers, ..Default::default() },
        )
        .expect("joiner coordinator"),
    );
    let handle =
        serve_listener(coordinator.clone(), joiner_listener).expect("listener");
    backends.push((coordinator, handle));

    // run the same load WHILE the join (warm-up + epoch roll +
    // admission + drop pass) executes on another thread
    let (join_reply, (qps_during, fail_during)) = std::thread::scope(|s| {
        let router = router.clone();
        let joiner_addr = all_addrs[N].clone();
        let join = s.spawn(move || router.join(&joiner_addr));
        let load = run_load("during");
        (join.join().expect("join thread"), load)
    });
    assert_eq!(
        join_reply.get("ok"),
        Some(&Json::Bool(true)),
        "join failed: {join_reply}"
    );
    let keys_streamed = join_reply
        .get("keys_streamed")
        .and_then(Json::as_f64)
        .unwrap_or(0.0);
    let keys_dropped = join_reply
        .get("keys_dropped")
        .and_then(Json::as_f64)
        .unwrap_or(0.0);

    let (qps_after, fail_after) = run_load("after");
    let kib_after = incumbent_kib(&backends);
    let joiner_kib =
        backends[N].0.live_index_bytes() as f64 / 1024.0;

    let mut csv = CsvTable::new(&[
        "phase",
        "qps",
        "failures",
        "incumbent_live_kib_mean",
        "joiner_live_kib",
        "keys_streamed",
        "keys_dropped",
        "ring_epoch",
    ]);
    let mut phases_json: Vec<Json> = Vec::new();
    for (phase, qps, failures, kib) in [
        ("before", qps_before, fail_before, kib_before),
        ("during", qps_during, fail_during, kib_before),
        ("after", qps_after, fail_after, kib_after),
    ] {
        println!(
            "  {phase:<7} {qps:>8.1} q/s  {failures} failures  \
             incumbent live index {kib:.1} KiB/backend"
        );
        csv.push(&[
            phase.to_string(),
            format!("{qps}"),
            failures.to_string(),
            format!("{kib}"),
            format!("{joiner_kib}"),
            format!("{keys_streamed}"),
            format!("{keys_dropped}"),
            router.ring_epoch().to_string(),
        ]);
        phases_json.push(Json::obj(vec![
            ("phase", Json::Str(phase.to_string())),
            ("qps", Json::Num(qps)),
            ("failures", Json::Num(failures as f64)),
            ("incumbent_live_kib_mean", Json::Num(kib)),
        ]));
    }
    println!(
        "  join: {keys_streamed:.0} keys streamed to the (initially \
         empty) joiner, {keys_dropped:.0} disowned keys dropped; \
         incumbents {kib_before:.1} -> {kib_after:.1} KiB (bound \
         ~{:.1}), joiner {joiner_kib:.1} KiB",
        kib_before * (N as f64) / (N as f64 + 1.0),
    );

    drop(router); // prober stops before its backends vanish
    for (coordinator, handle) in backends {
        handle.shutdown();
        coordinator.stop();
    }
    let join_out = match out.strip_suffix(".csv") {
        Some(stem) => format!("{stem}_join.csv"),
        None => format!("{out}_join.csv"),
    };
    csv.write_to(&join_out).expect("write join csv");
    println!("wrote {join_out}");
    Json::obj(vec![
        ("phases", Json::Arr(phases_json)),
        ("keys_streamed", Json::Num(keys_streamed)),
        ("keys_dropped", Json::Num(keys_dropped)),
        ("joiner_live_kib", Json::Num(joiner_kib)),
        ("csv", Json::Str(join_out)),
    ])
}

/// Both arms reply this exact line per request, so the measurement
/// isolates the serving core: connection bookkeeping, framing, and
/// scheduling — not request work.
const CONNSCALE_REPLY: &str = "{\"ok\":true}";

/// Reactor-arm service: zero request work.
struct FixedReply;

impl LineService for FixedReply {
    fn serve_line(&self, _line: &str, _queued: Duration, done: Completion) {
        done.reply(CONNSCALE_REPLY.to_string());
    }
}

/// The pre-reactor serving shape: accept loop, one OS thread per
/// accepted connection, blocking line IO — the baseline arm. Small
/// stacks, so the arm is limited by what the OS lets it *spawn*, not
/// by address space.
fn thread_per_conn_server(
    listener: TcpListener,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            if stop.load(Ordering::Relaxed) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let spawned = std::thread::Builder::new()
                .stack_size(64 * 1024)
                .spawn(move || {
                    let Ok(read_half) = stream.try_clone() else { return };
                    let mut writer = stream;
                    for line in BufReader::new(read_half).lines() {
                        if line.is_err()
                            || writer
                                .write_all(CONNSCALE_REPLY.as_bytes())
                                .is_err()
                            || writer.write_all(b"\n").is_err()
                        {
                            break;
                        }
                    }
                });
            // Err = the OS refused another thread; the connection just
            // drops and the sweep counts it as unsustained
            drop(spawned);
        }
    })
}

/// One request roundtrip per pass per connection, spread over a bounded
/// client worker pool (so 1000 hot *connections* do not need 1000
/// client threads). Returns the per-request latencies in nanoseconds —
/// requests that error or see EOF record nothing, which is how dropped
/// connections fall out of the sustained count.
fn sweep(conns: &mut [BufReader<TcpStream>], passes: usize) -> Vec<u64> {
    if conns.is_empty() {
        return Vec::new();
    }
    let workers = 16.min(conns.len());
    let chunk = conns.len().div_ceil(workers);
    std::thread::scope(|s| {
        let handles: Vec<_> = conns
            .chunks_mut(chunk)
            .map(|slice| {
                s.spawn(move || {
                    let mut lat = Vec::with_capacity(slice.len() * passes);
                    let mut line = String::new();
                    for _ in 0..passes {
                        for conn in slice.iter_mut() {
                            let t0 = Instant::now();
                            if conn.get_mut().write_all(b"ping\n").is_err() {
                                continue;
                            }
                            line.clear();
                            if matches!(conn.read_line(&mut line), Ok(n) if n > 0)
                            {
                                lat.push(t0.elapsed().as_nanos() as u64);
                            }
                        }
                    }
                    lat
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("sweep worker"))
            .collect()
    })
}

/// Open up to `n` connections; stops early when the OS runs out of
/// descriptors — the point where the *client* side caps the experiment.
fn open_conns(addr: std::net::SocketAddr, n: usize) -> Vec<BufReader<TcpStream>> {
    let mut conns = Vec::with_capacity(n);
    for _ in 0..n {
        let Ok(s) = TcpStream::connect(addr) else { break };
        let _ = s.set_read_timeout(Some(Duration::from_secs(10)));
        let _ = s.set_nodelay(true);
        conns.push(BufReader::new(s));
    }
    conns
}

/// The PR-7 acceptance scenario: `connscale-idle` connections squat
/// (admitted, then silent) while `connscale-hot` connections exchange
/// request lines, against the reactor serving core and against
/// thread-per-connection. "Sustained" is measured, not assumed: at the
/// end every connection — idle and hot — must still complete a
/// roundtrip to count.
fn connscale_scenario(args: &Args, out: &str) -> Json {
    let idle_target: usize = args.num_or("connscale-idle", 10_000);
    let hot_target: usize = args.num_or("connscale-hot", 1_000);
    let passes: usize = args.num_or("connscale-passes", 3).max(1);

    println!(
        "\nconnection scaling ({idle_target} idle + {hot_target} hot \
         clients, {passes} roundtrips/hot conn):"
    );
    let mut csv = CsvTable::new(&[
        "design",
        "idle_target",
        "hot_target",
        "sustained_conns",
        "requests",
        "p50_us",
        "p99_us",
        "max_ms",
    ]);
    let mut arms_json: Vec<Json> = Vec::new();
    for design in ["reactor", "thread-per-conn"] {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let mut reactor = None;
        let mut baseline = None;
        if design == "reactor" {
            let config = ServerConfig {
                // unlimited admission, no reaping: idle squatters are
                // the load, and capacity is what's being measured
                max_connections: 0,
                idle_timeout: Duration::ZERO,
                ..ServerConfig::default()
            };
            reactor = Some(
                serve_lines(
                    listener,
                    Arc::new(FixedReply),
                    config,
                    Arc::new(ServerStats::default()),
                )
                .expect("reactor server"),
            );
        } else {
            baseline = Some(thread_per_conn_server(listener, stop.clone()));
        }

        let mut idle = open_conns(addr, idle_target);
        let mut hot = open_conns(addr, hot_target);

        // the hot phase, timed per request
        let mut lat = sweep(&mut hot, passes);
        let requests = lat.len();
        lat.sort_unstable();
        let pick = |q: f64| {
            if lat.is_empty() {
                0
            } else {
                lat[((lat.len() - 1) as f64 * q) as usize]
            }
        };
        let (p50_us, p99_us) = (
            pick(0.50) as f64 / 1_000.0,
            pick(0.99) as f64 / 1_000.0,
        );
        let max_ms = lat.last().copied().unwrap_or(0) as f64 / 1e6;

        // verification: every connection still alive counts once
        let sustained = sweep(&mut idle, 1).len() + sweep(&mut hot, 1).len();
        println!(
            "  {design:<16} sustained {sustained:>6} conns  hot p50 \
             {p50_us:>8.1} us  p99 {p99_us:>8.1} us  max {max_ms:>7.2} ms  \
             ({requests} requests)"
        );
        csv.push(&[
            design.to_string(),
            idle_target.to_string(),
            hot_target.to_string(),
            sustained.to_string(),
            requests.to_string(),
            format!("{p50_us}"),
            format!("{p99_us}"),
            format!("{max_ms}"),
        ]);
        arms_json.push(Json::obj(vec![
            ("design", Json::Str(design.to_string())),
            ("sustained_conns", Json::Num(sustained as f64)),
            ("requests", Json::Num(requests as f64)),
            ("p50_us", Json::Num(p50_us)),
            ("p99_us", Json::Num(p99_us)),
            ("max_ms", Json::Num(max_ms)),
        ]));

        drop(idle);
        drop(hot);
        if let Some(mut h) = reactor.take() {
            h.shutdown();
        }
        if let Some(t) = baseline.take() {
            stop.store(true, Ordering::Relaxed);
            // unblock the accept loop so it observes the stop flag
            let _ = TcpStream::connect(addr);
            let _ = t.join();
        }
    }
    let conn_out = match out.strip_suffix(".csv") {
        Some(stem) => format!("{stem}_connscale.csv"),
        None => format!("{out}_connscale.csv"),
    };
    csv.write_to(&conn_out).expect("write connscale csv");
    println!("wrote {conn_out}");
    Json::obj(vec![
        ("arms", Json::Arr(arms_json)),
        ("csv", Json::Str(conn_out)),
    ])
}

/// The PR-8 acceptance arm: the same skewed client load against one
/// TCP coordinator with tracing disabled (`trace_sample_every: 0`,
/// the default — span recording short-circuits on the unsampled id)
/// and with every query traced. The headline number is the throughput
/// delta between the arms; the acceptance bar is < 3%, checked from
/// the JSON summary rather than asserted here (bench containers are
/// too noisy for a hard perf gate).
fn obs_overhead_scenario(args: &Args, out: &str) -> Json {
    let queries: usize = args.num_or("router-queries", 384);
    let clients: usize = args.num_or("router-clients", 8).max(1);
    let workers: usize = args.num_or("router-workers", 2);
    let trees: usize = args.num_or("router-trees", 60);

    let ds = HospitalDataset::generate(HospitalConfig {
        trees,
        ..HospitalConfig::default()
    });
    let forest = Arc::new(ds.build_forest());
    let workload = Workload::generate(
        &forest,
        WorkloadConfig {
            entities_per_query: 1,
            queries: 64,
            zipf_s: 0.0,
            deep_bias: 0.0,
            ..Default::default()
        },
    );

    println!(
        "\nobservability overhead (1 coordinator, {queries} queries, \
         {clients} clients, tracing off vs every-query):"
    );
    let mut csv = CsvTable::new(&["tracing", "qps", "delta_pct_vs_off"]);
    let mut arms_json: Vec<Json> = Vec::new();
    let mut qps_off = 0.0f64;
    for (label, every) in [("off", 0u64), ("every-query", 1u64)] {
        let engine: Arc<dyn Engine> = Arc::new(NativeEngine::new());
        let coordinator = Arc::new(
            Coordinator::start(
                forest.clone(),
                corpus_from_texts(&ds.documents()),
                engine,
                RagConfig {
                    trace_sample_every: every,
                    ..RagConfig::default()
                },
                CoordinatorConfig { workers, ..Default::default() },
            )
            .expect("coordinator"),
        );
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().unwrap();
        let handle =
            serve_listener(coordinator.clone(), listener).expect("listener");

        {
            let mut warm = BufReader::new(
                TcpStream::connect(addr).expect("warmup connect"),
            );
            let mut line = String::new();
            for q in workload.queries.iter().take(8) {
                warm.get_mut()
                    .write_all(format!("{}\n", q.text).as_bytes())
                    .expect("warmup write");
                line.clear();
                warm.read_line(&mut line).expect("warmup read");
            }
        }

        let t0 = Instant::now();
        std::thread::scope(|s| {
            for c in 0..clients {
                let workload = &workload;
                let share =
                    queries / clients + usize::from(c < queries % clients);
                s.spawn(move || {
                    let mut conn = BufReader::new(
                        TcpStream::connect(addr).expect("client connect"),
                    );
                    let mut line = String::new();
                    for i in 0..share {
                        let q = &workload.queries
                            [(c + i * clients) % workload.queries.len()];
                        conn.get_mut()
                            .write_all(format!("{}\n", q.text).as_bytes())
                            .expect("client write");
                        line.clear();
                        conn.read_line(&mut line).expect("client read");
                        assert!(
                            line.contains("\"ok\":true"),
                            "query failed: {line}"
                        );
                    }
                });
            }
        });
        let qps = queries as f64 / t0.elapsed().as_secs_f64();
        if qps_off == 0.0 {
            qps_off = qps;
        }
        let delta_pct = (qps_off / qps - 1.0) * 100.0;
        println!(
            "  tracing {label:<12} {qps:>8.1} q/s  \
             delta vs off {delta_pct:>+6.2}%"
        );
        csv.push(&[
            label.to_string(),
            format!("{qps}"),
            format!("{delta_pct}"),
        ]);
        arms_json.push(Json::obj(vec![
            ("tracing", Json::Str(label.to_string())),
            ("qps", Json::Num(qps)),
            ("delta_pct_vs_off", Json::Num(delta_pct)),
        ]));

        handle.shutdown();
        coordinator.stop();
    }
    let obs_out = match out.strip_suffix(".csv") {
        Some(stem) => format!("{stem}_obs.csv"),
        None => format!("{out}_obs.csv"),
    };
    csv.write_to(&obs_out).expect("write obs csv");
    println!("wrote {obs_out}");
    Json::obj(vec![
        ("arms", Json::Arr(arms_json)),
        ("csv", Json::Str(obs_out)),
    ])
}
