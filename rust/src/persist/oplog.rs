//! Append-only log of acknowledged dynamic-update ops, with per-record
//! CRC and fsync-on-ack batching.
//!
//! Every `\x01insert` / `\x01delete` the coordinator acknowledges is
//! first appended here; with `fsync_every = 1` (the default) the record
//! is fsynced before the append returns, so **an acked write is a
//! durable write**. A `\x01repartition` additionally appends an `Epoch`
//! record, which is how a warm restart knows which membership epoch it
//! last served.
//!
//! Record layout (little-endian):
//!
//! ```text
//! len   u32   body length in bytes
//! crc   u32   CRC-32 of the body
//! body  len B op tag (u8) + op-specific payload
//! ```
//!
//! ## Torn-tail policy (replay)
//!
//! A SIGKILL or power cut can leave a partial record at the end of the
//! file. Replay distinguishes two failure shapes:
//!
//! * **Torn tail** — the final record's header or body runs past EOF,
//!   or the final complete record fails its CRC (a partially persisted
//!   write). The tail is truncated off and replay returns the longest
//!   valid prefix; since an un-synced record was by definition never
//!   acked, nothing acknowledged is lost.
//! * **Mid-log corruption** — a CRC mismatch on a record *followed by
//!   more data*. That is not a torn write; it means the disk lied.
//!   Replay refuses **loudly** with [`io::ErrorKind::InvalidData`]
//!   rather than silently dropping acknowledged history.

use std::fs::{File, OpenOptions};
use std::io::{self, Read, Write};
use std::path::Path;

use super::crc::crc32;
use crate::forest::EntityAddress;

/// One logged operation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum LogOp {
    /// An acknowledged `\x01insert`: one new occurrence of `entity`.
    Insert { entity: String, addr: EntityAddress },
    /// An acknowledged `\x01delete`: the entity's entry dropped.
    Delete { entity: String },
    /// A `\x01repartition` advanced the served membership epoch.
    Epoch(u64),
}

const TAG_INSERT: u8 = 1;
const TAG_DELETE: u8 = 2;
const TAG_EPOCH: u8 = 3;

impl LogOp {
    /// Encode the record body (tag + payload; no header).
    fn encode_body(&self) -> Vec<u8> {
        match self {
            LogOp::Insert { entity, addr } => {
                let e = entity.as_bytes();
                let mut b = Vec::with_capacity(11 + e.len());
                b.push(TAG_INSERT);
                b.extend_from_slice(&addr.tree.to_le_bytes());
                b.extend_from_slice(&addr.node.to_le_bytes());
                b.extend_from_slice(&(e.len() as u16).to_le_bytes());
                b.extend_from_slice(e);
                b
            }
            LogOp::Delete { entity } => {
                let e = entity.as_bytes();
                let mut b = Vec::with_capacity(3 + e.len());
                b.push(TAG_DELETE);
                b.extend_from_slice(&(e.len() as u16).to_le_bytes());
                b.extend_from_slice(e);
                b
            }
            LogOp::Epoch(e) => {
                let mut b = Vec::with_capacity(9);
                b.push(TAG_EPOCH);
                b.extend_from_slice(&e.to_le_bytes());
                b
            }
        }
    }

    /// Encode a full record (header + body).
    pub fn encode(&self) -> Vec<u8> {
        let body = self.encode_body();
        let mut out = Vec::with_capacity(8 + body.len());
        out.extend_from_slice(&(body.len() as u32).to_le_bytes());
        out.extend_from_slice(&crc32(&body).to_le_bytes());
        out.extend_from_slice(&body);
        out
    }

    /// Decode a record body (the CRC has already been verified).
    fn decode_body(body: &[u8]) -> Result<LogOp, String> {
        let take_str = |b: &[u8]| -> Result<String, String> {
            if b.len() < 2 {
                return Err("truncated entity length".into());
            }
            let n = u16::from_le_bytes([b[0], b[1]]) as usize;
            if b.len() != 2 + n {
                return Err("entity length disagrees with body".into());
            }
            String::from_utf8(b[2..].to_vec())
                .map_err(|_| "entity is not UTF-8".into())
        };
        match body.split_first() {
            Some((&TAG_INSERT, rest)) => {
                if rest.len() < 8 {
                    return Err("truncated insert payload".into());
                }
                let tree = u32::from_le_bytes(rest[..4].try_into().unwrap());
                let node = u32::from_le_bytes(rest[4..8].try_into().unwrap());
                Ok(LogOp::Insert {
                    entity: take_str(&rest[8..])?,
                    addr: EntityAddress::new(tree, node),
                })
            }
            Some((&TAG_DELETE, rest)) => {
                Ok(LogOp::Delete { entity: take_str(rest)? })
            }
            Some((&TAG_EPOCH, rest)) => {
                if rest.len() != 8 {
                    return Err("epoch payload is not 8 bytes".into());
                }
                Ok(LogOp::Epoch(u64::from_le_bytes(rest.try_into().unwrap())))
            }
            Some((tag, _)) => Err(format!("unknown op tag {tag}")),
            None => Err("empty record body".into()),
        }
    }
}

/// How replay left the log's tail.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TailOutcome {
    /// Every byte parsed as a valid record.
    Clean,
    /// A torn final record was truncated off (`dropped_bytes` of it).
    Truncated { dropped_bytes: u64 },
}

/// Replay result: the valid op prefix plus what happened at the tail.
#[derive(Clone, Debug)]
pub struct Replay {
    /// Decoded operations, in append order.
    pub ops: Vec<LogOp>,
    /// Tail disposition (a torn tail was already truncated on disk by
    /// [`OpLog::open`]; [`replay_bytes`] only reports it).
    pub tail: TailOutcome,
    /// Byte offset of the end of the valid prefix.
    pub valid_len: u64,
}

/// Parse a log image: the longest valid record prefix, torn-tail
/// detection, and loud refusal of mid-log corruption (see the module
/// docs for the policy).
pub fn replay_bytes(bytes: &[u8]) -> io::Result<Replay> {
    let mut ops = Vec::new();
    let mut pos = 0usize;
    while pos < bytes.len() {
        let remaining = bytes.len() - pos;
        if remaining < 8 {
            // torn header at EOF
            return Ok(torn(ops, pos, remaining));
        }
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap())
            as usize;
        let stored_crc =
            u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap());
        if remaining - 8 < len {
            // body runs past EOF: torn write (this also covers a
            // bit-flipped length field on the final record — the
            // inflated length overruns EOF and the record is dropped)
            return Ok(torn(ops, pos, remaining));
        }
        let body = &bytes[pos + 8..pos + 8 + len];
        if crc32(body) != stored_crc {
            if pos + 8 + len == bytes.len() {
                // final complete record, bad CRC: partially persisted
                return Ok(torn(ops, pos, remaining));
            }
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!(
                    "corrupt op log: record at byte {pos} fails its CRC \
                     with {} bytes following — not a torn tail; refusing \
                     to silently drop acknowledged history",
                    bytes.len() - (pos + 8 + len)
                ),
            ));
        }
        match LogOp::decode_body(body) {
            Ok(op) => ops.push(op),
            Err(why) => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!(
                        "corrupt op log: record at byte {pos} passed its \
                         CRC but does not decode ({why})"
                    ),
                ));
            }
        }
        pos += 8 + len;
    }
    Ok(Replay { ops, tail: TailOutcome::Clean, valid_len: pos as u64 })
}

fn torn(ops: Vec<LogOp>, valid: usize, dropped: usize) -> Replay {
    Replay {
        ops,
        tail: TailOutcome::Truncated { dropped_bytes: dropped as u64 },
        valid_len: valid as u64,
    }
}

/// The append handle: open-replay-truncate on startup, then append
/// records with fsync-on-ack batching.
#[derive(Debug)]
pub struct OpLog {
    file: File,
    /// Records appended since the last fsync.
    unsynced: u32,
    /// Fsync after every N appends (1 = strictest: fsync-per-ack).
    fsync_every: u32,
    /// Lifetime appended-record count.
    pub appended: u64,
    /// Lifetime fsync count.
    pub fsyncs: u64,
}

impl OpLog {
    /// Open (creating if absent) the log at `path`, replay its valid
    /// prefix, and truncate any torn tail **on disk** so later appends
    /// extend a clean log. Returns the handle positioned at the end
    /// plus the replayed ops. `fsync_every = N` batches durability:
    /// every Nth append fsyncs (so at most N-1 acked-but-unsynced
    /// records can be lost to a crash — only `1` gives the strict
    /// ack-after-durable guarantee).
    pub fn open(path: &Path, fsync_every: u32) -> io::Result<(OpLog, Replay)> {
        assert!(fsync_every >= 1, "fsync_every must be >= 1");
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(path)?;
        let mut bytes = Vec::new();
        file.read_to_end(&mut bytes)?;
        let replay = replay_bytes(&bytes)?;
        if matches!(replay.tail, TailOutcome::Truncated { .. }) {
            file.set_len(replay.valid_len)?;
            file.sync_all()?;
        }
        // position at the end of the valid prefix for appends
        use std::io::Seek;
        file.seek(io::SeekFrom::Start(replay.valid_len))?;
        Ok((
            OpLog { file, unsynced: 0, fsync_every, appended: 0, fsyncs: 0 },
            replay,
        ))
    }

    /// Append one record; fsyncs when the batching policy says so.
    /// Returns `true` when this append was made durable (the caller may
    /// only ack the client after a `true`, or after a later
    /// [`sync`](OpLog::sync)).
    pub fn append(&mut self, op: &LogOp) -> io::Result<bool> {
        self.file.write_all(&op.encode())?;
        self.appended += 1;
        self.unsynced += 1;
        if self.unsynced >= self.fsync_every {
            self.sync()?;
            return Ok(true);
        }
        Ok(false)
    }

    /// Force the log durable (fsync). Idempotent when nothing is
    /// pending.
    pub fn sync(&mut self) -> io::Result<()> {
        if self.unsynced > 0 {
            self.file.sync_data()?;
            self.fsyncs += 1;
            self.unsynced = 0;
        }
        Ok(())
    }

    /// Truncate the log to empty (after a snapshot made it redundant).
    /// Durable before return.
    pub fn reset(&mut self) -> io::Result<()> {
        use std::io::Seek;
        self.file.set_len(0)?;
        self.file.seek(io::SeekFrom::Start(0))?;
        self.file.sync_all()?;
        self.unsynced = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;

    fn tmp(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join(format!("cft-oplog-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d.join("oplog.cft")
    }

    fn sample_ops() -> Vec<LogOp> {
        vec![
            LogOp::Insert {
                entity: "cardiology".into(),
                addr: EntityAddress::new(3, 14),
            },
            LogOp::Epoch(2),
            LogOp::Delete { entity: "ward 3".into() },
            LogOp::Insert {
                entity: "icu".into(),
                addr: EntityAddress::new(0, 0),
            },
        ]
    }

    #[test]
    fn append_reopen_replays_in_order() {
        let path = tmp("roundtrip");
        let ops = sample_ops();
        {
            let (mut log, replay) = OpLog::open(&path, 1).unwrap();
            assert!(replay.ops.is_empty());
            for op in &ops {
                assert!(log.append(op).unwrap(), "fsync_every=1 is durable");
            }
        }
        let (_, replay) = OpLog::open(&path, 1).unwrap();
        assert_eq!(replay.ops, ops);
        assert_eq!(replay.tail, TailOutcome::Clean);
    }

    #[test]
    fn fsync_batching_counts_syncs() {
        let path = tmp("batch");
        let (mut log, _) = OpLog::open(&path, 3).unwrap();
        let op = LogOp::Epoch(1);
        assert!(!log.append(&op).unwrap());
        assert!(!log.append(&op).unwrap());
        assert!(log.append(&op).unwrap(), "third append syncs");
        assert_eq!(log.fsyncs, 1);
        log.sync().unwrap();
        assert_eq!(log.fsyncs, 1, "sync with nothing pending is a no-op");
    }

    #[test]
    fn torn_tail_is_truncated_and_reopen_is_clean() {
        let path = tmp("torn");
        let ops = sample_ops();
        {
            let (mut log, _) = OpLog::open(&path, 1).unwrap();
            for op in &ops {
                log.append(op).unwrap();
            }
        }
        // tear the final record mid-body
        let bytes = fs::read(&path).unwrap();
        fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        let (_, replay) = OpLog::open(&path, 1).unwrap();
        assert_eq!(replay.ops, ops[..ops.len() - 1].to_vec());
        assert!(matches!(replay.tail, TailOutcome::Truncated { .. }));
        // the truncation happened on disk: a second open is clean
        let (_, replay2) = OpLog::open(&path, 1).unwrap();
        assert_eq!(replay2.tail, TailOutcome::Clean);
        assert_eq!(replay2.ops, ops[..ops.len() - 1].to_vec());
    }

    #[test]
    fn final_record_with_bad_crc_is_a_torn_tail() {
        let path = tmp("tailcrc");
        let ops = sample_ops();
        {
            let (mut log, _) = OpLog::open(&path, 1).unwrap();
            for op in &ops {
                log.append(op).unwrap();
            }
        }
        let mut bytes = fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 1] ^= 0xFF; // flip a bit in the final body byte
        let replay = replay_bytes(&bytes).unwrap();
        assert_eq!(replay.ops, ops[..ops.len() - 1].to_vec());
        assert!(matches!(replay.tail, TailOutcome::Truncated { .. }));
    }

    #[test]
    fn midlog_corruption_is_refused_loudly() {
        let ops = sample_ops();
        let mut bytes = Vec::new();
        for op in &ops {
            bytes.extend_from_slice(&op.encode());
        }
        // flip a body bit of the FIRST record: later records follow, so
        // this must error, not truncate
        bytes[10] ^= 0x01;
        let err = replay_bytes(&bytes).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("corrupt op log"), "{err}");
    }

    #[test]
    fn reset_empties_durably() {
        let path = tmp("reset");
        let (mut log, _) = OpLog::open(&path, 1).unwrap();
        log.append(&LogOp::Epoch(9)).unwrap();
        log.reset().unwrap();
        log.append(&LogOp::Delete { entity: "x".into() }).unwrap();
        let (_, replay) = OpLog::open(&path, 1).unwrap();
        assert_eq!(replay.ops, vec![LogOp::Delete { entity: "x".into() }]);
    }

    #[test]
    fn empty_log_replays_empty() {
        let replay = replay_bytes(&[]).unwrap();
        assert!(replay.ops.is_empty());
        assert_eq!(replay.tail, TailOutcome::Clean);
    }
}
