//! Benchmark harness + the drivers regenerating every table/figure in
//! the paper's evaluation. The `rust/benches/*.rs` targets are thin
//! shells over [`experiments`].

pub mod experiments;
pub mod harness;

pub use harness::{bench, print_table, BenchResult};
