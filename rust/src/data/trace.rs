//! Serving trace record/replay: persist a query workload (with arrival
//! offsets) as JSON so serving experiments are reproducible across runs
//! and machines, and so real traces can be replayed against the
//! coordinator later (`examples/serve_requests --trace-*`).

use std::path::Path;

use crate::data::workload::Workload;
use crate::error::{CftError, Result};
use crate::util::json::Json;

/// One traced request.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRecord {
    /// Arrival offset from trace start, in microseconds.
    pub offset_us: u64,
    /// Query text.
    pub query: String,
}

/// A recorded query trace.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct QueryTrace {
    pub records: Vec<TraceRecord>,
}

impl QueryTrace {
    /// Build a trace from a workload at a fixed arrival rate (req/s).
    /// `rate <= 0` means all requests arrive at t=0 (closed-loop burst).
    pub fn from_workload(workload: &Workload, rate_per_s: f64) -> QueryTrace {
        let gap_us = if rate_per_s > 0.0 {
            (1e6 / rate_per_s) as u64
        } else {
            0
        };
        QueryTrace {
            records: workload
                .queries
                .iter()
                .enumerate()
                .map(|(i, q)| TraceRecord {
                    offset_us: gap_us * i as u64,
                    query: q.text.clone(),
                })
                .collect(),
        }
    }

    /// Serialize to JSON text.
    pub fn to_json(&self) -> String {
        Json::obj(vec![
            ("version", Json::Num(1.0)),
            (
                "records",
                Json::Arr(
                    self.records
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("offset_us", Json::Num(r.offset_us as f64)),
                                ("query", Json::Str(r.query.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
        .to_string()
    }

    /// Parse from JSON text.
    pub fn from_json(text: &str) -> Result<QueryTrace> {
        let doc = Json::parse(text)
            .map_err(|e| CftError::Config(format!("bad trace: {e}")))?;
        let records = doc
            .get("records")
            .and_then(Json::as_arr)
            .ok_or_else(|| CftError::Config("trace missing 'records'".into()))?
            .iter()
            .map(|r| {
                Ok(TraceRecord {
                    offset_us: r
                        .get("offset_us")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| {
                            CftError::Config("record missing offset_us".into())
                        })? as u64,
                    query: r
                        .get("query")
                        .and_then(Json::as_str)
                        .ok_or_else(|| {
                            CftError::Config("record missing query".into())
                        })?
                        .to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(QueryTrace { records })
    }

    /// Write to a file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json())?;
        Ok(())
    }

    /// Read from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<QueryTrace> {
        Self::from_json(&std::fs::read_to_string(path)?)
    }

    /// Number of records.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::hospital::{HospitalConfig, HospitalDataset};
    use crate::data::workload::WorkloadConfig;

    fn workload() -> Workload {
        let f = HospitalDataset::generate(HospitalConfig {
            trees: 4,
            ..HospitalConfig::default()
        })
        .build_forest();
        Workload::generate(&f, WorkloadConfig { queries: 5, ..Default::default() })
    }

    #[test]
    fn json_roundtrip() {
        let t = QueryTrace::from_workload(&workload(), 100.0);
        let back = QueryTrace::from_json(&t.to_json()).unwrap();
        assert_eq!(t, back);
        assert_eq!(back.len(), 5);
        assert_eq!(back.records[1].offset_us, 10_000);
    }

    #[test]
    fn burst_trace_all_at_zero() {
        let t = QueryTrace::from_workload(&workload(), 0.0);
        assert!(t.records.iter().all(|r| r.offset_us == 0));
    }

    #[test]
    fn file_roundtrip() {
        let t = QueryTrace::from_workload(&workload(), 50.0);
        let path = std::env::temp_dir().join("cft_trace_test.json");
        t.save(&path).unwrap();
        let back = QueryTrace::load(&path).unwrap();
        assert_eq!(t, back);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn bad_json_rejected() {
        assert!(QueryTrace::from_json("{}").is_err());
        assert!(QueryTrace::from_json("not json").is_err());
    }
}
