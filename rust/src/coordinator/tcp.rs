//! Minimal TCP line protocol in front of the coordinator: one query per
//! line in, one JSON object per line out. `cft-rag serve --port N`.
//! The full wire format — request lines, control lines, and every reply
//! field — is specified in `docs/PROTOCOL.md`; this module is its
//! backend-side implementation (the router front door in `router/`
//! speaks the same lines).
//!
//! Serving runs on the nonblocking reactor
//! ([`crate::reactor::server`]): one event-loop thread drives the
//! accept loop and a per-connection protocol state machine, instead of
//! one OS thread per accepted connection. Control lines are answered
//! synchronously on the reactor thread (they are index metadata
//! operations); queries hand off to the coordinator's batcher/worker
//! pool via [`Coordinator::submit_with`] and the reply is queued back
//! to the connection when the worker finishes — so a slow retrieval
//! never blocks the event loop, and replies on one connection always
//! come back in request order (strict pipelining, see
//! `docs/PROTOCOL.md`). Connection limits and idle reaping come from
//! [`RagConfig::max_connections`] / [`RagConfig::idle_timeout`]
//! (`docs/OPERATIONS.md` §Connection limits and timeouts).
//!
//! Protocol extras beyond plain queries (all parsed by
//! [`parse_control`]; the `\x01` prefix keeps control lines out of the
//! natural-language query space):
//!
//! * `:quit` closes the connection.
//! * [`STATS_REQUEST`] (`\x01stats`) returns the coordinator's
//!   [`MetricsSnapshot`](crate::coordinator::MetricsSnapshot) as one
//!   JSON line — stamped with live serving-pressure gauges
//!   (`open_connections`, `reactor_queue_depth`, `overloaded_rejects`,
//!   `idle_deadlines_expired`) — the shard router's health prober uses
//!   it to observe backend *load*, and it is handy for single-node ops
//!   too.
//! * [`INSERT_REQUEST`] (`\x01insert <tree> <node> <entity…>`) and
//!   [`DELETE_REQUEST`] (`\x01delete <entity…>`) apply dynamic
//!   entity-index point updates (paper §5 / Algorithm 2) through
//!   [`Coordinator::update_entity`] / [`Coordinator::remove_entity`],
//!   replying `{"ok":…,"applied":…}` — the ack the router's replicated
//!   write path counts against its quorum.
//! * Elastic-membership lines (`router/rebalance.rs` drives these):
//!   [`DUMP_REQUEST`] (`\x01dump <entity…>`) reads a key's indexed
//!   addresses off a current replica, [`REPARTITION_REQUEST`]
//!   (`\x01repartition <epoch> <replicas> <index> <addr,…>`) installs
//!   the next membership epoch's [`KeyPartition`] on a live backend,
//!   and [`PURGE_REQUEST`] (`\x01purge`) runs the incumbents'
//!   disowned-key drop pass. [`JOIN_REQUEST`]/[`DRAIN_REQUEST`] are
//!   **router front-door** verbs; a backend answers them `ok:false`.
//!   The `\x01stats` payload carries `partition_epoch`, which the
//!   router's prober matches before (re-)admitting a backend.
//!
//! [`KeyPartition`]: crate::rag::config::KeyPartition
//! [`RagConfig::max_connections`]: crate::rag::config::RagConfig::max_connections
//! [`RagConfig::idle_timeout`]: crate::rag::config::RagConfig::idle_timeout
//!
//! Serving comes in three lifetimes: [`serve`] (runs until the process
//! dies — the CLI path), [`serve_with_shutdown`], which returns a
//! [`ServeHandle`] whose `shutdown()` stops the reactor and joins it —
//! so tests (the router's especially) can start and stop real TCP
//! backends in-process without leaking listeners — and
//! [`serve_listener`], the pre-bound-listener form: a key-partitioned
//! fleet must fix every backend's address *before* any index is built,
//! so callers bind all listeners first, build each coordinator with its
//! [`KeyPartition`](crate::rag::config::KeyPartition), then serve.

use std::net::{SocketAddr, TcpListener};
use std::time::Duration;

use crate::coordinator::server::{Coordinator, ServeResponse};
use crate::error::Result;
use crate::obs::trace::{self, TraceId};
use crate::reactor::server::{
    serve_lines, Completion, LineService, ServerConfig, ServerHandle,
    ServerStats,
};
use crate::sync::time::Instant;
use crate::sync::Arc;
use crate::util::json::Json;
use crate::util::log;

/// Reserved control line: a client sending exactly this line receives
/// the coordinator's metrics snapshot as a JSON line instead of a query
/// reply.
pub const STATS_REQUEST: &str = "\x01stats";

/// Control-line verb for dynamic entity-index inserts:
/// `\x01insert <tree> <node> <entity…>` (the entity name is the greedy
/// tail — names contain spaces). See `docs/PROTOCOL.md`.
pub const INSERT_REQUEST: &str = "\x01insert";

/// Control-line verb for dynamic entity-index deletes:
/// `\x01delete <entity…>`. See `docs/PROTOCOL.md`.
pub const DELETE_REQUEST: &str = "\x01delete";

/// Control-line verb dumping an entity's indexed address list:
/// `\x01dump <entity…>` — the read half of the rebalancer's hinted
/// handoff (`router/rebalance.rs`). See `docs/PROTOCOL.md`.
pub const DUMP_REQUEST: &str = "\x01dump";

/// Control-line verb installing the next membership epoch's partition:
/// `\x01repartition <epoch> <replicas> <index> <addr,addr,…>`
/// (`replicas` 0 = full index). See `docs/PROTOCOL.md`.
pub const REPARTITION_REQUEST: &str = "\x01repartition";

/// Control-line verb for the incumbents' post-rebalance drop pass:
/// `\x01purge` reclaims every key the current partition no longer
/// owns. See `docs/PROTOCOL.md`.
pub const PURGE_REQUEST: &str = "\x01purge";

/// Control-line verb cutting a durability snapshot now: `\x01snapshot`
/// exports the live index into `<data-dir>/snapshot.cft` (atomic
/// write) and truncates the op log. Errors on a backend started
/// without `--data-dir`. See `docs/PROTOCOL.md`.
pub const SNAPSHOT_REQUEST: &str = "\x01snapshot";

/// Router front-door verb: `\x01join <addr>` rebalances a new backend
/// into the serving ring. Backends reject it. See `docs/PROTOCOL.md`.
pub const JOIN_REQUEST: &str = "\x01join";

/// Router front-door verb: `\x01drain <addr>` hands a leaving
/// backend's keys off and removes it from the serving ring. Backends
/// reject it. See `docs/PROTOCOL.md`.
pub const DRAIN_REQUEST: &str = "\x01drain";

/// Control-line verb exporting recently sampled request traces:
/// `\x01trace` (recent) or `\x01trace <id>` (one trace by hex id) —
/// the reply is the span tree JSON from [`crate::obs::trace`]. See
/// `docs/PROTOCOL.md` and `docs/OBSERVABILITY.md`.
pub const TRACE_REQUEST: &str = "\x01trace";

/// Control-line verb returning the unified metrics registry in
/// Prometheus text exposition format, wrapped as one JSON line
/// (`{"ok":true,"content_type":…,"text":…}`). See `docs/PROTOCOL.md`.
pub const METRICS_REQUEST: &str = "\x01metrics";

/// A parsed `\x01` control line (`docs/PROTOCOL.md` §Control lines).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ControlLine<'a> {
    /// `\x01stats` — metrics snapshot.
    Stats,
    /// `\x01insert <tree> <node> <entity…>` — register one occurrence.
    Insert { tree: u32, node: u32, entity: &'a str },
    /// `\x01delete <entity…>` — drop an entity from the index.
    Delete { entity: &'a str },
    /// `\x01dump <entity…>` — the entity's indexed addresses.
    Dump { entity: &'a str },
    /// `\x01repartition <epoch> <replicas> <index> <addr,addr,…>` —
    /// install the next membership epoch's key partition (`replicas`
    /// 0 clears it: full index).
    Repartition {
        epoch: u64,
        replicas: usize,
        index: usize,
        backends: &'a str,
    },
    /// `\x01purge` — drop every key the current partition disowns.
    Purge,
    /// `\x01snapshot` — cut a durability snapshot now (requires
    /// `--data-dir`).
    Snapshot,
    /// `\x01join <addr>` — router front door: rebalance a backend in.
    Join { addr: &'a str },
    /// `\x01drain <addr>` — router front door: rebalance a backend out.
    Drain { addr: &'a str },
    /// `\x01trace [id]` — recently sampled request traces (optionally
    /// filtered to one hex trace id).
    Trace { id: Option<&'a str> },
    /// `\x01metrics` — Prometheus text exposition of the metrics
    /// registry.
    Metrics,
}

/// Parse a control line. Returns `None` when `line` is not a control
/// line at all (a plain query), and `Some(Err(reason))` for a malformed
/// or unknown one — the server answers those with `ok:false` rather
/// than treating binary junk as a natural-language query.
#[allow(clippy::type_complexity)]
pub fn parse_control(
    line: &str,
) -> Option<std::result::Result<ControlLine<'_>, String>> {
    let body = line.strip_prefix('\x01')?;
    let (verb, rest) = match body.split_once(' ') {
        Some((v, r)) => (v, r.trim()),
        None => (body, ""),
    };
    Some(match verb {
        "stats" if rest.is_empty() => Ok(ControlLine::Stats),
        "stats" => Err("\\x01stats takes no arguments".into()),
        "insert" => {
            let mut it = rest.splitn(3, ' ');
            let tree = it.next().unwrap_or("").parse::<u32>();
            let node = it.next().unwrap_or("").parse::<u32>();
            let entity = it.next().unwrap_or("").trim();
            match (tree, node) {
                (Ok(tree), Ok(node)) if !entity.is_empty() => {
                    Ok(ControlLine::Insert { tree, node, entity })
                }
                _ => Err(
                    "\\x01insert wants: <tree> <node> <entity...>".into()
                ),
            }
        }
        "delete" if !rest.is_empty() => {
            Ok(ControlLine::Delete { entity: rest })
        }
        "delete" => Err("\\x01delete wants: <entity...>".into()),
        "dump" if !rest.is_empty() => Ok(ControlLine::Dump { entity: rest }),
        "dump" => Err("\\x01dump wants: <entity...>".into()),
        "repartition" => {
            let mut it = rest.splitn(4, ' ');
            let epoch = it.next().unwrap_or("").parse::<u64>();
            let replicas = it.next().unwrap_or("").parse::<usize>();
            let index = it.next().unwrap_or("").parse::<usize>();
            let backends = it.next().unwrap_or("").trim();
            match (epoch, replicas, index) {
                (Ok(epoch), Ok(replicas), Ok(index))
                    if !backends.is_empty() =>
                {
                    Ok(ControlLine::Repartition {
                        epoch,
                        replicas,
                        index,
                        backends,
                    })
                }
                _ => Err("\\x01repartition wants: <epoch> <replicas> \
                          <index> <addr,addr,...>"
                    .into()),
            }
        }
        "purge" if rest.is_empty() => Ok(ControlLine::Purge),
        "purge" => Err("\\x01purge takes no arguments".into()),
        "snapshot" if rest.is_empty() => Ok(ControlLine::Snapshot),
        "snapshot" => Err("\\x01snapshot takes no arguments".into()),
        "join" if !rest.is_empty() => Ok(ControlLine::Join { addr: rest }),
        "join" => Err("\\x01join wants: <addr>".into()),
        "drain" if !rest.is_empty() => Ok(ControlLine::Drain { addr: rest }),
        "drain" => Err("\\x01drain wants: <addr>".into()),
        "trace" if rest.is_empty() => Ok(ControlLine::Trace { id: None }),
        "trace" => Ok(ControlLine::Trace { id: Some(rest) }),
        "metrics" if rest.is_empty() => Ok(ControlLine::Metrics),
        "metrics" => Err("\\x01metrics takes no arguments".into()),
        other => Err(format!("unknown control line {other:?}")),
    })
}

/// Serve until the process is killed: bind, start the reactor, and
/// block on its event-loop thread. The CLI path.
pub fn serve(coordinator: Arc<Coordinator>, addr: &str) -> Result<()> {
    let mut handle = serve_listener(coordinator, TcpListener::bind(addr)?)?;
    handle.inner.wait();
    Ok(())
}

/// Bind `addr` and serve on a background reactor thread; the returned
/// handle stops the listener on demand. Bind to port 0 for an
/// ephemeral port (the handle reports the resolved address).
pub fn serve_with_shutdown(
    coordinator: Arc<Coordinator>,
    addr: &str,
) -> Result<ServeHandle> {
    serve_listener(coordinator, TcpListener::bind(addr)?)
}

/// [`serve_with_shutdown`] over an **already-bound** listener. This is
/// how a key-partitioned fleet starts: every backend's address must be
/// known before any index is built (the partition hashes the address
/// list), so callers bind all N listeners first, then build each
/// coordinator with its partition, then hand the listeners here.
pub fn serve_listener(
    coordinator: Arc<Coordinator>,
    listener: TcpListener,
) -> Result<ServeHandle> {
    let local = listener.local_addr()?;
    let stats = Arc::new(ServerStats::default());
    let config = ServerConfig {
        max_connections: coordinator.max_connections(),
        idle_timeout: coordinator.idle_timeout(),
        ..ServerConfig::default()
    };
    let service = Arc::new(CoordinatorService {
        coordinator,
        stats: Arc::clone(&stats),
    });
    let inner = serve_lines(listener, service, config, stats)?;
    log::info!("cft-rag listening on {local} (nonblocking reactor)");
    Ok(ServeHandle { inner })
}

/// A running TCP front end that can be stopped.
pub struct ServeHandle {
    inner: ServerHandle,
}

impl ServeHandle {
    /// The bound address (resolved — useful after binding port 0).
    pub fn addr(&self) -> SocketAddr {
        self.inner.addr()
    }

    /// The front end's live serving-pressure counters (also stamped
    /// into every `\x01stats` reply).
    pub fn stats(&self) -> Arc<ServerStats> {
        self.inner.stats()
    }

    /// Stop the reactor and join its thread. Open connections are
    /// dropped (in-flight worker replies are discarded at the closed
    /// completion queue); the listener socket itself is released
    /// before this returns, so the port can be rebound.
    pub fn shutdown(mut self) {
        self.inner.shutdown();
    }
}

/// The coordinator's [`LineService`] implementation — one per served
/// listener. Control lines are answered synchronously on the reactor
/// thread (index metadata operations, not retrievals); plain queries
/// go through [`Coordinator::submit_with`], whose worker-side callback
/// queues the reply back onto the connection's reactor, so a slow
/// retrieval never stalls the event loop.
struct CoordinatorService {
    coordinator: Arc<Coordinator>,
    /// Shared with the reactor loop; read when composing `\x01stats`.
    stats: Arc<ServerStats>,
}

impl LineService for CoordinatorService {
    fn serve_line(&self, line: &str, queued: Duration, done: Completion) {
        if self.coordinator.is_stopped() {
            // behave like a dead process: close instead of answering —
            // a live `\x01stats` on a stopped backend would hide its
            // death from the router's health prober
            done.close();
            return;
        }
        // An upstream front door (the router) may prefix any line with
        // `\x01t=<id> ` to propagate its trace id; peel it before verb
        // dispatch so every verb — `:quit` included — works traced.
        let (wire_trace, line) = trace::strip_trace(line);
        if line == ":quit" {
            done.close();
            return;
        }
        let c = &self.coordinator;
        let reply = match parse_control(line) {
            Some(Ok(ControlLine::Stats)) => stats_reply(c, &self.stats),
            Some(Ok(ControlLine::Trace { id })) => trace_reply(id),
            Some(Ok(ControlLine::Metrics)) => metrics_reply(c),
            Some(Ok(ControlLine::Insert { tree, node, entity })) => {
                update_ack(c.update_entity(entity, tree, node))
            }
            Some(Ok(ControlLine::Delete { entity })) => {
                update_ack(c.remove_entity(entity))
            }
            Some(Ok(ControlLine::Dump { entity })) => dump_reply(c, entity),
            Some(Ok(ControlLine::Repartition {
                epoch,
                replicas,
                index,
                backends,
            })) => repartition_reply(c, epoch, replicas, index, backends),
            Some(Ok(ControlLine::Purge)) => purge_reply(c),
            Some(Ok(ControlLine::Snapshot)) => snapshot_reply(c),
            Some(Ok(
                ControlLine::Join { .. } | ControlLine::Drain { .. },
            )) => Json::obj(vec![
                ("ok", Json::Bool(false)),
                (
                    "error",
                    Json::Str(
                        "join/drain are router front-door control lines; \
                         send them to the router, not a backend"
                            .into(),
                    ),
                ),
            ]),
            Some(Err(reason)) => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::Str(reason)),
            ]),
            None => {
                // A query. Adopt the wire trace when the upstream door
                // already sampled this request; otherwise roll the
                // local head sampler. The reactor-queue span is backed
                // out of the `queued` duration the reactor measured
                // (zero when the line was dispatched on arrival).
                let trace = if wire_trace.is_sampled() {
                    wire_trace
                } else {
                    c.sampler().begin()
                };
                let start = Instant::now();
                if trace.is_sampled() && !queued.is_zero() {
                    trace::record(
                        trace,
                        trace::Stage::ReactorQueue,
                        0,
                        start,
                        queued,
                    );
                }
                let owned = line.to_string();
                let c = Arc::clone(&self.coordinator);
                self.coordinator.submit_traced(
                    line,
                    trace,
                    Box::new(move |out| {
                        let total = start.elapsed();
                        let slow = c.sampler().is_slow(total);
                        // Slow queries are always traced: when head
                        // sampling skipped this request, mint an id so
                        // the slow-query log line and the `\x01trace`
                        // export still carry a root record (root-only —
                        // stage spans cannot be recorded retroactively).
                        let trace = if slow && !trace.is_sampled() {
                            trace::mint()
                        } else {
                            trace
                        };
                        trace::finish_root(
                            trace,
                            trace::DOOR_COORDINATOR,
                            start,
                            total,
                            slow,
                        );
                        if slow {
                            trace::log_slow(
                                trace::DOOR_COORDINATOR,
                                trace,
                                total,
                                &owned,
                            );
                        }
                        done.reply(query_reply(out, trace).to_string());
                    }),
                );
                return;
            }
        };
        done.reply(reply.to_string());
    }
}

/// The `\x01stats` payload: the coordinator's metrics snapshot stamped
/// with the backend's `partition_epoch` — what the router's health
/// prober matches against the serving ring's epoch before
/// (re-)admitting the backend — plus the front end's live
/// serving-pressure gauges (`docs/PROTOCOL.md` §Stats).
fn stats_reply(coordinator: &Coordinator, serving: &ServerStats) -> Json {
    let mut json = coordinator.metrics().snapshot().to_json();
    if let Json::Obj(m) = &mut json {
        m.insert(
            "partition_epoch".into(),
            Json::Num(coordinator.partition_epoch() as f64),
        );
        m.insert(
            "open_connections".into(),
            Json::Num(serving.open_connections() as f64),
        );
        m.insert(
            "reactor_queue_depth".into(),
            Json::Num(serving.reactor_queue_depth() as f64),
        );
        m.insert(
            "overloaded_rejects".into(),
            Json::Num(serving.overloaded_rejects() as f64),
        );
        m.insert(
            "idle_deadlines_expired".into(),
            Json::Num(serving.idle_deadlines_expired() as f64),
        );
        m.insert(
            "uptime_s".into(),
            Json::Num(coordinator.uptime().as_secs_f64()),
        );
        m.insert(
            "version".into(),
            Json::Str(env!("CARGO_PKG_VERSION").to_string()),
        );
        m.insert(
            "build_profile".into(),
            Json::Str(
                if cfg!(debug_assertions) { "debug" } else { "release" }
                    .to_string(),
            ),
        );
        if let Some(telemetry) = coordinator.filter_telemetry() {
            m.insert("filter".into(), telemetry.to_json());
        }
        if coordinator.context_cache().enabled() {
            let c = coordinator.context_cache().stats();
            m.insert(
                "context_cache".into(),
                Json::obj(vec![
                    ("hits", Json::Num(c.hits as f64)),
                    ("misses", Json::Num(c.misses as f64)),
                    (
                        "invalidations",
                        Json::Num(c.invalidations as f64),
                    ),
                    (
                        "entries",
                        Json::Num(
                            coordinator.context_cache().len() as f64
                        ),
                    ),
                ]),
            );
        }
        if let Some(d) = coordinator.durability() {
            m.insert(
                "durability".into(),
                Json::obj(vec![
                    (
                        "log_records_appended",
                        Json::Num(d.log_records_appended as f64),
                    ),
                    ("log_fsyncs", Json::Num(d.log_fsyncs as f64)),
                    ("log_replayed", Json::Num(d.log_replayed as f64)),
                    (
                        "log_truncated_bytes",
                        Json::Num(d.log_truncated_bytes as f64),
                    ),
                    (
                        "snapshots_written",
                        Json::Num(d.snapshots_written as f64),
                    ),
                    ("snapshot_loaded", Json::Bool(d.snapshot_loaded)),
                    (
                        "ops_since_snapshot",
                        Json::Num(d.ops_since_snapshot as f64),
                    ),
                ]),
            );
        }
    }
    json
}

/// The `\x01trace` reply: recently sampled traces as a span-tree JSON
/// document, optionally filtered to one hex trace id. An unparsable id
/// is an error reply (an empty `traces` array would be
/// indistinguishable from "not sampled"). Shared with the router front
/// door — the trace hub is process-wide, so both doors export the same
/// way.
pub(crate) fn trace_reply(id: Option<&str>) -> Json {
    match id {
        None => trace::export_json(None, 16),
        Some(hex) => match TraceId::from_hex(hex) {
            Some(t) => trace::export_json(Some(t), 1),
            None => Json::obj(vec![
                ("ok", Json::Bool(false)),
                (
                    "error",
                    Json::Str(format!("bad trace id {hex:?}")),
                ),
            ]),
        },
    }
}

/// The `\x01metrics` reply: the unified registry rendered in Prometheus
/// text exposition format, wrapped in a one-line JSON envelope so the
/// line protocol stays one-reply-per-line (the exposition itself is
/// multi-line; the JSON string escapes the newlines).
fn metrics_reply(coordinator: &Coordinator) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        (
            "content_type",
            Json::Str("text/plain; version=0.0.4".to_string()),
        ),
        ("text", Json::Str(coordinator.metrics().registry().render())),
    ])
}

/// The `\x01dump` reply: the entity's indexed addresses on this
/// backend, as `{"tree":…,"node":…}` pairs (empty when not held) — the
/// source side of the rebalancer's `\x01insert` handoff replay.
fn dump_reply(coordinator: &Coordinator, entity: &str) -> Json {
    let addrs = coordinator.dump_entity(entity);
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("entity", Json::Str(entity.to_string())),
        (
            "addresses",
            Json::Arr(
                addrs
                    .iter()
                    .map(|a| {
                        Json::obj(vec![
                            ("tree", Json::Num(a.tree as f64)),
                            ("node", Json::Num(a.node as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// The `\x01repartition` handler: build and install the next epoch's
/// [`KeyPartition`](crate::rag::config::KeyPartition) (`replicas` 0
/// clears the partition — full index — while still advancing the
/// reported epoch, which is how an unpartitioned fleet tracks
/// membership changes).
fn repartition_reply(
    coordinator: &Coordinator,
    epoch: u64,
    replicas: usize,
    index: usize,
    backends: &str,
) -> Json {
    let outcome = if replicas == 0 {
        coordinator.set_partition(None, epoch)
    } else {
        let addrs: Vec<&str> = backends
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .collect();
        crate::rag::config::KeyPartition::new(addrs, index, replicas)
            .and_then(|p| {
                coordinator.set_partition(Some(p.with_epoch(epoch)), epoch)
            })
    };
    match outcome {
        Ok(()) => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("partition_epoch", Json::Num(epoch as f64)),
            ("replicas", Json::Num(replicas as f64)),
        ]),
        Err(e) => Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("error", Json::Str(e.to_string())),
        ]),
    }
}

/// The `\x01snapshot` reply: how many live entries the snapshot
/// captured (the op log is truncated alongside — its records are now
/// folded into the snapshot).
fn snapshot_reply(coordinator: &Coordinator) -> Json {
    match coordinator.trigger_snapshot() {
        Ok(n) => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("entries", Json::Num(n as f64)),
            (
                "partition_epoch",
                Json::Num(coordinator.partition_epoch() as f64),
            ),
        ]),
        Err(e) => Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("error", Json::Str(e.to_string())),
        ]),
    }
}

/// The `\x01purge` reply: how many disowned keys the drop pass
/// reclaimed.
fn purge_reply(coordinator: &Coordinator) -> Json {
    match coordinator.drop_disowned() {
        Ok(n) => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("dropped", Json::Num(n as f64)),
        ]),
        Err(e) => Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("error", Json::Str(e.to_string())),
        ]),
    }
}

/// The one-line ack for a dynamic-update control line: `ok` is whether
/// the backend processed the request, `applied` whether the index
/// actually changed (a deleted-but-absent key acks `applied:false`).
fn update_ack(outcome: Result<bool>) -> Json {
    match outcome {
        Ok(applied) => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("applied", Json::Bool(applied)),
        ]),
        Err(e) => Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("applied", Json::Bool(false)),
            ("error", Json::Str(e.to_string())),
        ]),
    }
}

/// Build the JSON reply for one query, synchronously (exposed for
/// tests and the thread-per-connection bench baseline).
pub fn respond(coordinator: &Coordinator, query: &str) -> Json {
    query_reply(coordinator.query_blocking(query), TraceId::NONE)
}

/// One query outcome as its wire JSON — shared by [`respond`] and the
/// nonblocking path's worker callback. A sampled `trace` stamps the
/// reply with the request's hex trace id so a client can fetch the
/// span tree afterwards (`\x01trace <id>`); unsampled replies carry no
/// `trace` field, keeping the old wire shape byte-compatible.
fn query_reply(out: Result<ServeResponse>, trace: TraceId) -> Json {
    match out {
        Ok(r) => {
            let mut fields = vec![
                ("ok", Json::Bool(true)),
                ("answer", Json::Str(r.answer)),
                (
                    "entities",
                    Json::Arr(
                        r.entities.into_iter().map(Json::Str).collect(),
                    ),
                ),
                ("facts", Json::Num(r.fact_count as f64)),
                (
                    "retrieval_us",
                    Json::Num(r.retrieval_time.as_micros() as f64),
                ),
                ("total_ms", Json::Num(r.total_time.as_millis() as f64)),
            ];
            if trace.is_sampled() {
                fields.push(("trace", Json::Str(trace.to_hex())));
            }
            Json::obj(fields)
        }
        Err(e) => Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("error", Json::Str(e.to_string())),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::CoordinatorConfig;
    use crate::data::corpus::corpus_from_texts;
    use crate::data::hospital::{HospitalConfig, HospitalDataset};
    use crate::rag::config::RagConfig;
    use crate::runtime::engine::{Engine, NativeEngine};
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    fn coordinator_with(rag: RagConfig) -> Arc<Coordinator> {
        let ds = HospitalDataset::generate(HospitalConfig {
            trees: 4,
            ..HospitalConfig::default()
        });
        let forest = Arc::new(ds.build_forest());
        let docs = corpus_from_texts(&ds.documents());
        let engine: Arc<dyn Engine> = Arc::new(NativeEngine::new());
        Arc::new(
            Coordinator::start(
                forest,
                docs,
                engine,
                rag,
                CoordinatorConfig { workers: 2, ..Default::default() },
            )
            .unwrap(),
        )
    }

    fn coordinator() -> Arc<Coordinator> {
        coordinator_with(RagConfig::default())
    }

    fn served(c: Arc<Coordinator>) -> ServeHandle {
        serve_listener(c, TcpListener::bind("127.0.0.1:0").unwrap()).unwrap()
    }

    #[test]
    fn respond_builds_json() {
        let c = coordinator();
        let json = respond(&c, "describe the hierarchy around cardiology");
        assert_eq!(json.get("ok"), Some(&Json::Bool(true)));
        assert!(json.get("answer").unwrap().as_str().unwrap().len() > 10);
    }

    #[test]
    fn tcp_roundtrip() {
        let handle = served(coordinator());
        let mut client = TcpStream::connect(handle.addr()).unwrap();
        client
            .write_all(b"what is the parent unit of cardiology\n:quit\n")
            .unwrap();
        let mut reader = BufReader::new(client);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":true"), "{line}");
        // :quit closes the connection from the server side
        line.clear();
        assert_eq!(reader.read_line(&mut line).unwrap(), 0);
    }

    #[test]
    fn pipelined_lines_reply_in_request_order() {
        let handle = served(coordinator());
        let mut client = TcpStream::connect(handle.addr()).unwrap();
        // a burst of sync control lines around an async query: replies
        // must come back in request order even though the query detours
        // through the worker pool while the stats lines are answered on
        // the reactor thread
        client
            .write_all(
                b"\x01stats\n\
                  what is the parent unit of cardiology\n\
                  \x01stats\n:quit\n",
            )
            .unwrap();
        let mut reader = BufReader::new(client);
        let mut next = || {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            Json::parse(line.trim()).expect("reply is JSON")
        };
        let before = next();
        assert_eq!(
            before.get("requests").and_then(Json::as_f64),
            Some(0.0),
            "{before}"
        );
        let answer = next();
        assert_eq!(answer.get("ok"), Some(&Json::Bool(true)), "{answer}");
        assert!(answer.get("answer").is_some(), "{answer}");
        // the trailing stats line was held behind the query: it must
        // observe the completed request
        let after = next();
        assert_eq!(
            after.get("requests").and_then(Json::as_f64),
            Some(1.0),
            "{after}"
        );
    }

    #[test]
    fn stats_reply_reports_serving_pressure() {
        let handle = served(coordinator());
        let mut client = TcpStream::connect(handle.addr()).unwrap();
        client.write_all(b"\x01stats\n:quit\n").unwrap();
        let mut reader = BufReader::new(client);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let snap = Json::parse(line.trim()).expect("stats reply is JSON");
        // this connection is the one open connection, and the stats
        // line itself is the one dispatched-but-uncompleted request at
        // the moment the reply is composed
        assert_eq!(
            snap.get("open_connections").and_then(Json::as_f64),
            Some(1.0),
            "{snap}"
        );
        assert_eq!(
            snap.get("reactor_queue_depth").and_then(Json::as_f64),
            Some(1.0),
            "{snap}"
        );
        assert_eq!(
            snap.get("overloaded_rejects").and_then(Json::as_f64),
            Some(0.0),
            "{snap}"
        );
        assert_eq!(
            snap.get("idle_deadlines_expired").and_then(Json::as_f64),
            Some(0.0),
            "{snap}"
        );
    }

    #[test]
    fn stopped_coordinator_drops_connections_instead_of_answering() {
        let c = coordinator();
        let handle = served(c.clone());
        let mut client = TcpStream::connect(handle.addr()).unwrap();
        c.stop();
        // even the stats control line must NOT be answered once the
        // coordinator is stopped — the router's prober relies on a dead
        // backend going silent, not serving stale control replies
        client.write_all(b"\x01stats\n").unwrap();
        let mut reader = BufReader::new(client);
        let mut line = String::new();
        let n = reader.read_line(&mut line).unwrap();
        assert_eq!(n, 0, "expected EOF, got {line:?}");
    }

    #[test]
    fn serve_with_shutdown_stops_and_releases_port() {
        let c = coordinator();
        let handle = serve_with_shutdown(c, "127.0.0.1:0").unwrap();
        let addr = handle.addr();
        // served while up
        let mut client = TcpStream::connect(addr).unwrap();
        client
            .write_all(b"what is the parent unit of cardiology\n:quit\n")
            .unwrap();
        let mut reader = BufReader::new(client);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":true"), "{line}");
        // stops without hanging, and the port is rebindable — the
        // listener did not leak
        handle.shutdown();
        TcpListener::bind(addr).expect("port released after shutdown");
    }

    #[test]
    fn parse_control_lines() {
        assert_eq!(parse_control("plain query"), None);
        assert_eq!(parse_control("\x01stats"), Some(Ok(ControlLine::Stats)));
        assert_eq!(
            parse_control("\x01insert 3 14 ward 9"),
            Some(Ok(ControlLine::Insert { tree: 3, node: 14, entity: "ward 9" }))
        );
        assert_eq!(
            parse_control("\x01delete intensive care"),
            Some(Ok(ControlLine::Delete { entity: "intensive care" }))
        );
        assert_eq!(
            parse_control("\x01dump ward 9"),
            Some(Ok(ControlLine::Dump { entity: "ward 9" }))
        );
        assert_eq!(
            parse_control("\x01repartition 2 1 0 a:1,b:2"),
            Some(Ok(ControlLine::Repartition {
                epoch: 2,
                replicas: 1,
                index: 0,
                backends: "a:1,b:2",
            }))
        );
        assert_eq!(parse_control("\x01purge"), Some(Ok(ControlLine::Purge)));
        assert_eq!(
            parse_control("\x01snapshot"),
            Some(Ok(ControlLine::Snapshot))
        );
        assert_eq!(
            parse_control("\x01join 127.0.0.1:7184"),
            Some(Ok(ControlLine::Join { addr: "127.0.0.1:7184" }))
        );
        assert_eq!(
            parse_control("\x01drain 127.0.0.1:7184"),
            Some(Ok(ControlLine::Drain { addr: "127.0.0.1:7184" }))
        );
        assert_eq!(
            parse_control("\x01trace"),
            Some(Ok(ControlLine::Trace { id: None }))
        );
        assert_eq!(
            parse_control("\x01trace a1b2c3"),
            Some(Ok(ControlLine::Trace { id: Some("a1b2c3") }))
        );
        assert_eq!(
            parse_control("\x01metrics"),
            Some(Ok(ControlLine::Metrics))
        );
        for bad in [
            "\x01metrics now",
            "\x01stats now",
            "\x01insert",
            "\x01insert x y z",
            "\x01insert 1 2",
            "\x01delete",
            "\x01dump",
            "\x01repartition",
            "\x01repartition 1 2",
            "\x01repartition x 1 0 a:1",
            "\x01repartition 1 1 0",
            "\x01purge now",
            "\x01snapshot now",
            "\x01join",
            "\x01drain",
            "\x01launch missiles",
        ] {
            assert!(
                matches!(parse_control(bad), Some(Err(_))),
                "{bad:?} must be rejected"
            );
        }
    }

    #[test]
    fn rebalance_control_lines_roundtrip_over_tcp() {
        let handle = served(coordinator());
        let mut client = TcpStream::connect(handle.addr()).unwrap();
        client
            .write_all(
                b"\x01stats\n\
                  \x01dump cardiology\n\
                  \x01repartition 1 0 0 x:1\n\
                  \x01stats\n\
                  \x01purge\n\
                  \x01join 10.0.0.9:1\n\
                  :quit\n",
            )
            .unwrap();
        let mut reader = BufReader::new(client);
        let mut next = || {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            Json::parse(line.trim()).expect("reply is JSON")
        };
        // fresh backend reports epoch 0 in its stats payload
        let stats = next();
        assert_eq!(
            stats.get("partition_epoch").and_then(Json::as_f64),
            Some(0.0),
            "{stats}"
        );
        // dump returns the entity's address objects
        let dump = next();
        assert_eq!(dump.get("ok"), Some(&Json::Bool(true)), "{dump}");
        let addrs = dump.get("addresses").and_then(Json::as_arr).unwrap();
        assert!(!addrs.is_empty(), "{dump}");
        assert!(addrs[0].get("tree").and_then(Json::as_f64).is_some());
        assert!(addrs[0].get("node").and_then(Json::as_f64).is_some());
        // repartition with replicas=0 keeps the full index but advances
        // the reported epoch
        let rep = next();
        assert_eq!(rep.get("ok"), Some(&Json::Bool(true)), "{rep}");
        assert_eq!(
            rep.get("partition_epoch").and_then(Json::as_f64),
            Some(1.0)
        );
        let stats = next();
        assert_eq!(
            stats.get("partition_epoch").and_then(Json::as_f64),
            Some(1.0),
            "{stats}"
        );
        // purge on a full index drops nothing
        let purge = next();
        assert_eq!(purge.get("ok"), Some(&Json::Bool(true)), "{purge}");
        assert_eq!(purge.get("dropped").and_then(Json::as_f64), Some(0.0));
        // join is a router verb: backends refuse it
        let join = next();
        assert_eq!(join.get("ok"), Some(&Json::Bool(false)), "{join}");
    }

    #[test]
    fn snapshot_line_and_durability_stats_over_tcp() {
        let dir = std::env::temp_dir()
            .join(format!("cft-tcp-snap-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let handle = served(coordinator_with(RagConfig {
            data_dir: Some(dir.clone()),
            ..RagConfig::default()
        }));
        let mut client = TcpStream::connect(handle.addr()).unwrap();
        client
            .write_all(
                b"\x01delete cardiology\n\x01stats\n\x01snapshot\n\
                  \x01stats\n:quit\n",
            )
            .unwrap();
        let mut reader = BufReader::new(client);
        let mut next = || {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            Json::parse(line.trim()).expect("reply is JSON")
        };
        let del = next();
        assert_eq!(del.get("ok"), Some(&Json::Bool(true)), "{del}");
        // the acked delete shows up in the durability counters
        let stats = next();
        let d = stats.get("durability").expect("durability object");
        assert_eq!(
            d.get("log_records_appended").and_then(Json::as_f64),
            Some(1.0),
            "{stats}"
        );
        assert!(
            d.get("log_fsyncs").and_then(Json::as_f64) >= Some(1.0),
            "fsync-per-ack at the default --fsync-every 1: {stats}"
        );
        // snapshot folds the log and reports the entry count
        let snap = next();
        assert_eq!(snap.get("ok"), Some(&Json::Bool(true)), "{snap}");
        assert!(
            snap.get("entries").and_then(Json::as_f64) > Some(0.0),
            "{snap}"
        );
        let stats = next();
        let d = stats.get("durability").expect("durability object");
        assert_eq!(
            d.get("snapshots_written").and_then(Json::as_f64),
            Some(1.0),
            "{stats}"
        );
        assert_eq!(
            d.get("ops_since_snapshot").and_then(Json::as_f64),
            Some(0.0),
            "{stats}"
        );
        assert!(dir.join(crate::persist::SNAPSHOT_FILE).exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_line_errors_without_data_dir() {
        let handle = served(coordinator());
        let mut client = TcpStream::connect(handle.addr()).unwrap();
        client.write_all(b"\x01snapshot\n:quit\n").unwrap();
        let mut line = String::new();
        BufReader::new(client).read_line(&mut line).unwrap();
        let reply = Json::parse(line.trim()).unwrap();
        assert_eq!(reply.get("ok"), Some(&Json::Bool(false)), "{reply}");
        assert!(
            reply
                .get("error")
                .and_then(Json::as_str)
                .unwrap()
                .contains("data-dir"),
            "{reply}"
        );
    }

    #[test]
    fn update_control_lines_ack_over_tcp() {
        let handle = served(coordinator());
        let mut client = TcpStream::connect(handle.addr()).unwrap();
        // delete a known entity, idempotently re-delete, reject garbage
        client
            .write_all(
                b"\x01delete cardiology\n\x01delete cardiology\n\
                  \x01insert 0 99999 cardiology\n:quit\n",
            )
            .unwrap();
        let mut reader = BufReader::new(client);
        let mut expect = |ok: bool, applied: bool| {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let json = Json::parse(line.trim()).expect("ack is JSON");
            assert_eq!(json.get("ok"), Some(&Json::Bool(ok)), "{line}");
            assert_eq!(
                json.get("applied"),
                Some(&Json::Bool(applied)),
                "{line}"
            );
        };
        expect(true, true); // first delete applied
        expect(true, false); // second is an idempotent no-op
        expect(false, false); // out-of-range node rejected
    }

    #[test]
    fn traced_query_exports_spans_and_metrics() {
        let rag = RagConfig {
            trace_sample_every: 1,
            ..RagConfig::default()
        };
        let handle = served(coordinator_with(rag));
        let client = TcpStream::connect(handle.addr()).unwrap();
        let mut reader = BufReader::new(client);
        let mut send = |line: String| {
            reader.get_mut().write_all(line.as_bytes()).unwrap();
            let mut reply = String::new();
            reader.read_line(&mut reply).unwrap();
            Json::parse(reply.trim()).expect("reply is JSON")
        };
        let reply =
            send("what is the parent unit of cardiology\n".to_string());
        assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply}");
        let id = reply
            .get("trace")
            .and_then(Json::as_str)
            .expect("sampled reply carries its trace id")
            .to_string();
        // the span tree for that id is exported over \x01trace
        let traces = send(format!("\x01trace {id}\n"));
        assert_eq!(traces.get("ok"), Some(&Json::Bool(true)), "{traces}");
        let arr = traces.get("traces").and_then(Json::as_arr).unwrap();
        assert_eq!(arr.len(), 1, "{traces}");
        assert_eq!(arr[0].get("id").and_then(Json::as_str), Some(&*id));
        let spans = arr[0].get("spans").and_then(Json::as_arr).unwrap();
        assert!(!spans.is_empty(), "{traces}");
        for span in spans {
            assert!(span.get("stage").and_then(Json::as_str).is_some());
            assert!(
                span.get("dur_us").and_then(Json::as_f64).unwrap() >= 0.0
            );
        }
        // the metrics registry renders Prometheus text exposition
        let metrics = send("\x01metrics\n".to_string());
        assert_eq!(metrics.get("ok"), Some(&Json::Bool(true)), "{metrics}");
        let text =
            metrics.get("text").and_then(Json::as_str).unwrap();
        assert!(
            text.contains("cft_coordinator_requests_total 1"),
            "{text}"
        );
        assert!(text.contains("# TYPE"), "{text}");
        // stats carries the build/uptime satellites
        let stats = send("\x01stats\n".to_string());
        assert!(
            stats.get("uptime_s").and_then(Json::as_f64).unwrap() >= 0.0,
            "{stats}"
        );
        assert_eq!(
            stats.get("version").and_then(Json::as_str),
            Some(env!("CARGO_PKG_VERSION")),
            "{stats}"
        );
        let profile =
            stats.get("build_profile").and_then(Json::as_str).unwrap();
        assert!(profile == "debug" || profile == "release", "{stats}");
        reader.get_mut().write_all(b":quit\n").unwrap();
    }

    #[test]
    fn wire_trace_prefix_is_adopted_and_echoed() {
        // sampling disabled locally: the wire prefix alone must carry
        // the upstream door's sampling decision through to the reply
        let handle = served(coordinator());
        let mut client = TcpStream::connect(handle.addr()).unwrap();
        client
            .write_all(
                b"\x01t=abc123 what is the parent unit of cardiology\n\
                  :quit\n",
            )
            .unwrap();
        let mut reader = BufReader::new(client);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let reply = Json::parse(line.trim()).expect("reply is JSON");
        assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply}");
        assert_eq!(
            reply.get("trace").and_then(Json::as_str),
            Some("abc123"),
            "{reply}"
        );
    }
}
