//! Schedule-exploration suite (`--features modelcheck`): the
//! historical bug classes of the filter/coordinator core, encoded as
//! deterministic interleaving searches over the real data structures.
//!
//! Every test runs its body under [`cft_rag::modelcheck::explore`]:
//! many seeds, PCT-style forced preemptions, virtual time. A failure
//! panics with the seed and the exact `MODELCHECK_SEED=… cargo test`
//! line that replays it bit-for-bit. The bug classes covered:
//!
//! * **PR-1 entry loss on migration retry** — an entry evicted from
//!   the old generation had to survive a failed re-placement into the
//!   target. `migration_churn_never_loses_entries` re-runs that churn
//!   under every explored schedule;
//!   `checker_catches_reintroduced_entry_loss` proves the checker
//!   *would* flag the pre-fix protocol (remove-then-insert with a
//!   preemption window) if it were ever reintroduced.
//! * **PR-2 generation invariant** — a reader must observe every key
//!   in exactly one generation at every instant of an incremental
//!   doubling (`reader_observes_exactly_one_generation`).
//! * **PR-2 stale maintenance plans** — a temperature re-sort planned
//!   against a snapshot must reject (or harmlessly apply) after
//!   concurrent mutation (`stale_maintenance_plan_is_rejected_or_safe`).
//! * **Batcher submit/stop** — accepted jobs are delivered exactly
//!   once across a racing stop; a full queue bounds the submitter's
//!   wait in virtual time (`batcher_*` tests).
//! * **ISSUE-10 cache fill race** — a reply-cache fill that read
//!   backend state before a write's invalidation must not land after
//!   it (`cache_fill_never_resurrects_invalidated_replies` on the real
//!   [`ReplyCache`]; `checker_catches_unguarded_cache_fill` proves the
//!   checker would flag a token-less fill if it were reintroduced).

#![cfg(feature = "modelcheck")]

use std::time::Duration;

use cft_rag::filter::cuckoo::{CuckooConfig, CuckooFilter};
use cft_rag::filter::sharded::ShardedCuckooFilter;
use cft_rag::forest::address::EntityAddress;
use cft_rag::modelcheck::{explore, try_explore, Config};
use cft_rag::sync::{thread, Arc, Mutex, RwLock};

/// A table small enough that a handful of inserts forces a doubling,
/// stepped one bucket at a time so migrations stay pending across many
/// scheduling points.
fn tiny_cfg() -> CuckooConfig {
    CuckooConfig {
        initial_buckets: 2,
        slots: 4,
        load_threshold: 0.5,
        migration_step_buckets: 1,
        sort_by_temperature: false,
        ..CuckooConfig::default()
    }
}

fn addr(i: u32) -> EntityAddress {
    EntityAddress::new(i, i)
}

/// Exploration budget for the filter bodies: fewer seeds than the
/// checker's own unit tests (each schedule here walks a real filter),
/// a window sized to the bodies' actual step counts.
fn filter_cfg(iterations: u64) -> Config {
    Config {
        iterations,
        change_window: 256,
        max_steps: 50_000,
        ..Config::default()
    }
}

/// PR-1 bug class, on the real structure: stable keys must survive
/// expansion churn — concurrent fresh inserts forcing doublings, a
/// maintainer stepping the migration, and a delete/re-insert retry
/// loop — under every explored interleaving.
#[test]
fn migration_churn_never_loses_entries() {
    explore("migration_churn_never_loses_entries", &filter_cfg(24), || {
        let f = Arc::new(ShardedCuckooFilter::new(tiny_cfg(), 1));
        for k in 0..3u64 {
            assert!(f.insert(k, &[addr(k as u32)]));
        }

        let inserter = {
            let f = Arc::clone(&f);
            thread::spawn(move || {
                // enough fresh keys to force at least one doubling
                for k in 100..106u64 {
                    assert!(f.insert(k, &[addr(k as u32)]), "table full");
                }
            })
        };
        let maintainer = {
            let f = Arc::clone(&f);
            thread::spawn(move || {
                for _ in 0..4 {
                    f.maintain(); // steps any pending migration
                }
            })
        };
        let retrier = {
            let f = Arc::clone(&f);
            thread::spawn(move || {
                // the PR-1 shape: a key deleted and re-inserted while
                // buckets are migrating must land in exactly one place
                for _ in 0..2 {
                    assert!(f.delete(2));
                    assert!(f.insert(2, &[addr(2)]));
                }
            })
        };
        inserter.join().unwrap();
        maintainer.join().unwrap();
        retrier.join().unwrap();

        f.maintain();
        for k in (0..3u64).chain(100..106u64) {
            assert!(f.contains_exact(k), "key {k} lost in migration churn");
            let addrs = f.lookup_collect(k).expect("addresses lost");
            assert_eq!(addrs.len(), 1, "key {k} address list corrupted");
        }
    });
}

/// PR-2 generation invariant: while a doubling is stepped forward and
/// fresh inserts land in the target generation, a reader holding the
/// shard read-lock sees every stable key in exactly one generation —
/// never zero (lost), never two (duplicated).
#[test]
fn reader_observes_exactly_one_generation() {
    explore("reader_observes_exactly_one_generation", &filter_cfg(24), || {
        let mut filter = CuckooFilter::new(tiny_cfg());
        let mut k = 0u64;
        while !filter.migration_pending() {
            assert!(filter.insert(k, &[addr(k as u32)]), "table full");
            k += 1;
            assert!(k < 64, "expansion never triggered");
        }
        let stable = k; // keys 0..stable are in, migration in flight
        let f = Arc::new(RwLock::new(filter));

        let migrator = {
            let f = Arc::clone(&f);
            thread::spawn(move || {
                while f.write().unwrap().migrate_step() {}
            })
        };
        let inserter = {
            let f = Arc::clone(&f);
            thread::spawn(move || {
                for k in 200..203u64 {
                    assert!(
                        f.write().unwrap().insert(k, &[addr(k as u32)]),
                        "table full"
                    );
                }
            })
        };
        let reader = {
            let f = Arc::clone(&f);
            thread::spawn(move || {
                for _ in 0..4 {
                    let g = f.read().unwrap();
                    for k in 0..stable {
                        assert_eq!(
                            g.occurrences(k),
                            1,
                            "key {k} not in exactly one generation"
                        );
                    }
                }
            })
        };
        migrator.join().unwrap();
        inserter.join().unwrap();
        reader.join().unwrap();

        let g = f.read().unwrap();
        for k in (0..stable).chain(200..203u64) {
            assert_eq!(g.occurrences(k), 1, "key {k} duplicated or lost");
        }
    });
}

/// PR-2 stale-plan invariant: a temperature re-sort planned against a
/// read-locked snapshot races a mutator (delete + insert + address
/// push). Whatever `apply_bucket_plan` decides — apply or reject as
/// stale — no surviving key may be lost, duplicated, or detached from
/// its address list.
#[test]
fn stale_maintenance_plan_is_rejected_or_safe() {
    explore(
        "stale_maintenance_plan_is_rejected_or_safe",
        &filter_cfg(32),
        || {
            let cfg = CuckooConfig {
                initial_buckets: 4,
                slots: 4,
                sort_by_temperature: true,
                ..CuckooConfig::default()
            };
            let mut filter = CuckooFilter::new(cfg);
            for k in 0..6u64 {
                assert!(filter.insert(k, &[addr(k as u32)]));
            }
            // skew temperatures so the planner has re-sorts to propose
            for _ in 0..3 {
                let _ = filter.lookup_shared(5);
            }
            let f = Arc::new(RwLock::new(filter));

            let planner = {
                let f = Arc::clone(&f);
                thread::spawn(move || {
                    let plans = f.read().unwrap().plan_maintenance();
                    for plan in &plans {
                        // stale plans must return false, not corrupt
                        let _ = f.write().unwrap().apply_bucket_plan(plan);
                    }
                })
            };
            let mutator = {
                let f = Arc::clone(&f);
                thread::spawn(move || {
                    let mut g = f.write().unwrap();
                    assert!(g.delete(0));
                    drop(g);
                    let mut g = f.write().unwrap();
                    assert!(g.insert(300, &[addr(300)]));
                    drop(g);
                    assert!(f.write().unwrap().push_address(5, addr(55)));
                })
            };
            planner.join().unwrap();
            mutator.join().unwrap();

            let g = f.read().unwrap();
            assert_eq!(g.occurrences(0), 0, "deleted key resurrected");
            for k in (1..6u64).chain([300]) {
                assert_eq!(g.occurrences(k), 1, "key {k} lost or duplicated");
            }
            let hit = g.lookup_shared(5).expect("key 5 lost");
            assert_eq!(
                g.addresses(hit).len(),
                2,
                "pushed address detached by a stale re-sort"
            );
        },
    );
}

/// The demonstration that the suite has teeth: the *pre-PR-1* migration
/// protocol — remove the entry from the old generation, then insert it
/// into the target as a separate step — modeled with shim primitives.
/// The explorer must find the schedule where a reader lands in the
/// window and observes the key in zero generations.
#[test]
fn checker_catches_reintroduced_entry_loss() {
    let cfg = Config {
        iterations: 512,
        change_window: 24,
        max_steps: 20_000,
        ..Config::default()
    };
    let failure = try_explore(&cfg, || {
        // two generations of a one-key table
        let old_gen = Arc::new(Mutex::new(vec![7u64]));
        let new_gen = Arc::new(Mutex::new(Vec::<u64>::new()));
        let migrator = {
            let (o, n) = (Arc::clone(&old_gen), Arc::clone(&new_gen));
            thread::spawn(move || {
                // BUG (pre-PR-1): the entry leaves the old table before
                // it is placed in the new one — two critical sections
                // with a preemptible window between them
                let k = o.lock().unwrap().pop().unwrap();
                n.lock().unwrap().push(k);
            })
        };
        let occurrences = old_gen.lock().unwrap().len()
            + new_gen.lock().unwrap().len();
        assert_eq!(occurrences, 1, "key observed in {occurrences} generations");
        migrator.join().unwrap();
    })
    .expect_err("the entry-loss window must be discoverable");
    assert!(
        failure.report.contains("generations"),
        "wrong failure: {}",
        failure.report
    );
}

// ---------------------------------------------------------------------
// Batcher / coordinator submit path
// ---------------------------------------------------------------------

use cft_rag::coordinator::batcher::{collect_batch, BatchOutcome, BatchPolicy};
use cft_rag::sync::mpsc::{sync_channel, SyncSender, TrySendError};
use cft_rag::sync::time::Instant;

/// The coordinator's bounded enqueue, distilled (`coordinator/server.rs`
/// `enqueue`): try_send with a backoff sleep until a deadline. Under the
/// model the sleep is virtual — the full timeout costs no wall-clock.
fn enqueue_bounded(
    tx: &SyncSender<u32>,
    job: u32,
    max_wait: Duration,
) -> Result<(), &'static str> {
    let deadline = Instant::now() + max_wait;
    let mut job = job;
    loop {
        match tx.try_send(job) {
            Ok(()) => return Ok(()),
            Err(TrySendError::Disconnected(_)) => return Err("stopped"),
            Err(TrySendError::Full(j)) => {
                if Instant::now() >= deadline {
                    return Err("queue full");
                }
                job = j;
                thread::sleep(Duration::from_millis(1));
            }
        }
    }
}

/// Submit-vs-stop: jobs whose submit wins the race against `stop()` are
/// delivered to the batch loop exactly once; jobs that lose are refused
/// cleanly. Mirrors `Coordinator::submit`'s `Mutex<Option<Sender>>`
/// idiom, with the real `collect_batch` as the consumer.
#[test]
fn batcher_submit_vs_stop_loses_no_accepted_job() {
    explore(
        "batcher_submit_vs_stop_loses_no_accepted_job",
        &Config { iterations: 48, change_window: 256, ..Config::default() },
        || {
            let (tx, rx) = sync_channel::<u32>(1);
            let slot = Arc::new(Mutex::new(Some(tx)));
            let accepted = Arc::new(Mutex::new(Vec::<u32>::new()));

            let consumer = thread::spawn(move || {
                let policy = BatchPolicy {
                    max_batch: 2,
                    max_wait: Duration::from_millis(2),
                };
                let mut got = Vec::new();
                loop {
                    match collect_batch(&rx, policy) {
                        BatchOutcome::Batch { items, .. } => got.extend(items),
                        BatchOutcome::Closed => return got,
                    }
                }
            });

            let submitters: Vec<_> = (0..2u32)
                .map(|s| {
                    let slot = Arc::clone(&slot);
                    let accepted = Arc::clone(&accepted);
                    thread::spawn(move || {
                        for job in [s * 10, s * 10 + 1] {
                            // take the sender under the lock, send
                            // outside it — submit() exactly; a None
                            // slot is the clean "stopped" refusal
                            let tx = slot.lock().unwrap().clone();
                            if let Some(tx) = tx {
                                // a cloned sender outlives stop();
                                // the send must still deliver
                                tx.send(job).unwrap();
                                accepted.lock().unwrap().push(job);
                            }
                        }
                    })
                })
                .collect();
            let stopper = {
                let slot = Arc::clone(&slot);
                thread::spawn(move || {
                    drop(slot.lock().unwrap().take());
                })
            };

            for s in submitters {
                s.join().unwrap();
            }
            stopper.join().unwrap();
            let mut delivered = consumer.join().unwrap();
            let mut accepted = accepted.lock().unwrap().clone();
            delivered.sort_unstable();
            accepted.sort_unstable();
            assert_eq!(
                delivered, accepted,
                "accepted jobs must be delivered exactly once"
            );
        },
    );
}

/// Backpressure: with the queue full and no consumer, a bounded submit
/// waits out its (virtual) deadline and fails with "queue full"; once a
/// consumer drains, the same submit succeeds; after stop it reports
/// "stopped" immediately.
#[test]
fn batcher_enqueue_bounded_wait_on_full_queue() {
    explore(
        "batcher_enqueue_bounded_wait_on_full_queue",
        &Config { iterations: 32, change_window: 128, ..Config::default() },
        || {
            // full queue, nobody draining: must give up at the deadline
            let (tx, rx) = sync_channel::<u32>(1);
            tx.send(0).unwrap();
            let t = Instant::now();
            assert_eq!(
                enqueue_bounded(&tx, 1, Duration::from_millis(8)),
                Err("queue full")
            );
            assert!(t.elapsed() >= Duration::from_millis(8), "gave up early");

            // a consumer appears: the retry loop must get through
            let drainer = thread::spawn(move || {
                thread::sleep(Duration::from_millis(3));
                assert_eq!(rx.recv().unwrap(), 0);
                let next = rx.recv().unwrap();
                assert_eq!(next, 2);
            });
            assert_eq!(
                enqueue_bounded(&tx, 2, Duration::from_millis(50)),
                Ok(())
            );
            drainer.join().unwrap();

            // stopped coordinator: immediate, not a timeout
            let (tx, rx) = sync_channel::<u32>(1);
            drop(rx);
            let t = Instant::now();
            assert_eq!(
                enqueue_bounded(&tx, 3, Duration::from_millis(30)),
                Err("stopped")
            );
            assert_eq!(
                t.elapsed(),
                Duration::ZERO,
                "disconnect must not wait out the deadline"
            );
        },
    );
}

// ---------------------------------------------------------------------
// Reply-cache fill vs invalidation (ISSUE 10)
// ---------------------------------------------------------------------

use cft_rag::router::cache::{normalize_entities, ReplyCache};
use cft_rag::util::json::Json;

/// A reply stamped with the backend-state version it was assembled
/// from — the observable that makes staleness checkable.
fn versioned_reply(v: u64) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("degraded", Json::Bool(false)),
        ("answer", Json::Str(format!("v{v}"))),
    ])
}

/// The ISSUE-10 race, on the real [`ReplyCache`]: a filler thread that
/// misses, reads backend state, and admits through its [`FillToken`],
/// against a writer that mutates the state and then invalidates (the
/// router's broadcast order: backends apply, *then* the entity's
/// entries are dropped, *then* the ack returns). Under every explored
/// preemption, a hit after both threads retire may only serve the
/// post-write reply — the fill token must fence the window between the
/// filler's state read and its admit.
#[test]
fn cache_fill_never_resurrects_invalidated_replies() {
    explore(
        "cache_fill_never_resurrects_invalidated_replies",
        &Config { iterations: 64, change_window: 128, ..Config::default() },
        || {
            let cache = Arc::new(ReplyCache::new(64 * 1024));
            let ents = normalize_entities(vec!["cardiology".to_string()]);
            // the backend-side state the reply is assembled from
            let version = Arc::new(Mutex::new(0u64));

            let filler = {
                let cache = Arc::clone(&cache);
                let version = Arc::clone(&version);
                let ents = ents.clone();
                thread::spawn(move || {
                    for _ in 0..2 {
                        let (hit, token) = cache.lookup("q", &ents, 0);
                        if hit.is_none() {
                            // preemptible window: the state read and
                            // the admit are separate critical sections
                            let v = *version.lock().unwrap();
                            cache.admit(
                                "q",
                                &ents,
                                0,
                                &versioned_reply(v),
                                token,
                            );
                        }
                    }
                })
            };
            let writer = {
                let cache = Arc::clone(&cache);
                let version = Arc::clone(&version);
                thread::spawn(move || {
                    *version.lock().unwrap() += 1; // backends applied
                    cache.invalidate_entity("cardiology"); // before ack
                })
            };
            filler.join().unwrap();
            writer.join().unwrap();

            // the write has acked; only the post-write reply may serve
            let (hit, _) = cache.lookup("q", &ents, 0);
            if let Some(reply) = hit {
                assert_eq!(
                    reply.get("answer"),
                    Some(&Json::Str("v1".to_string())),
                    "stale pre-write reply survived the invalidation"
                );
            }
        },
    );
}

/// Teeth check: the same race against a cache WITHOUT the fill token —
/// read the state in one critical section, install the reply in
/// another, nothing fencing the gap. The explorer must find the
/// schedule where the write's bump-and-invalidate lands inside that
/// gap and the stale fill survives the ack; the returned
/// [`cft_rag::modelcheck::Failure`] carries the seed that replays it
/// (`MODELCHECK_SEED=<seed>`).
#[test]
fn checker_catches_unguarded_cache_fill() {
    let cfg = Config {
        iterations: 512,
        change_window: 24,
        max_steps: 20_000,
        ..Config::default()
    };
    let failure = try_explore(&cfg, || {
        let version = Arc::new(Mutex::new(0u64));
        let cached = Arc::new(Mutex::new(None::<u64>));
        let filler = {
            let (v, c) = (Arc::clone(&version), Arc::clone(&cached));
            thread::spawn(move || {
                // BUG (the pre-ISSUE-10 strawman): no token — an
                // invalidation between these two sections goes unseen
                let snapshot = *v.lock().unwrap();
                c.lock().unwrap().replace(snapshot);
            })
        };
        let writer = {
            let (v, c) = (Arc::clone(&version), Arc::clone(&cached));
            thread::spawn(move || {
                *v.lock().unwrap() += 1;
                c.lock().unwrap().take(); // the write's invalidation
            })
        };
        filler.join().unwrap();
        writer.join().unwrap();
        if let Some(got) = *cached.lock().unwrap() {
            let now = *version.lock().unwrap();
            assert_eq!(
                got, now,
                "stale reply (v{got}) cached past the write's ack (v{now})"
            );
        }
    })
    .expect_err("the unguarded-fill window must be discoverable");
    assert!(
        failure.report.contains("stale reply"),
        "wrong failure: {}",
        failure.report
    );
    // `failure.seed` is the replay handle; `modelcheck::mod` unit-tests
    // prove replaying a failing seed reproduces the identical schedule.
}
