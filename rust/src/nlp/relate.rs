//! Relationship extraction — paper §2.2.
//!
//! The paper uses dependency-parsing models (GPT-4 / NLP libraries) to
//! pull hierarchical (child, parent) relations out of text. Offline, we
//! implement the rule layer the paper describes on top of a pattern
//! matcher: dependency cues like "belongs to", "is part of", "contains",
//! prepositional "X of Y", appositives ("X, a unit of Y"), and
//! conjunction grouping ("A and B belong to C" puts both A and B under C).

use crate::text::normalize::{normalize, sentences};

/// An extracted (child, parent) relation with the matching rule name.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Relation {
    pub child: String,
    pub parent: String,
    /// which pattern produced this (for debugging/ablation)
    pub rule: &'static str,
}

impl Relation {
    fn new(child: &str, parent: &str, rule: &'static str) -> Option<Relation> {
        let child = clean_phrase(child);
        let parent = clean_phrase(parent);
        if child.is_empty() || parent.is_empty() {
            return None;
        }
        Some(Relation { child, parent, rule })
    }
}

/// Normalize an entity phrase and strip leading determiners.
fn clean_phrase(phrase: &str) -> String {
    let mut s = normalize(phrase);
    for det in ["the ", "a ", "an ", "its ", "their ", "our "] {
        if let Some(rest) = s.strip_prefix(det) {
            s = rest.to_string();
            break;
        }
    }
    s
}

/// Child-side dependency cues: `<child> CUE <parent>`. Grouped by the
/// §2.2 relationship categories (organizational, inclusion, functional,
/// attribute, geographic, temporal).
const CHILD_CUES: &[(&str, &str)] = &[
    // organizational
    (" belongs to ", "belongs-to"),
    (" belong to ", "belongs-to"),
    (" reports to ", "reports-to"),
    (" report to ", "reports-to"),
    (" is under ", "under"),
    (" operates under ", "under"),
    (" answers to ", "answers-to"),
    (" is attached to ", "attached-to"),
    // categorization / appositive-like copulas
    (" is a unit of ", "unit-of"),
    (" is a division of ", "division-of"),
    (" is a department of ", "department-of"),
    (" is a branch of ", "branch-of"),
    (" is a subsidiary of ", "subsidiary-of"),
    // inclusion
    (" is part of ", "part-of"),
    (" are part of ", "part-of"),
    (" is within ", "within"),
    (" is housed in ", "housed-in"),
    // functional
    (" is dependent on ", "dependent-on"),
    (" depends on ", "dependent-on"),
    (" is run by ", "run-by"),
    (" is operated by ", "operated-by"),
    (" is administered by ", "administered-by"),
    // geographic
    (" is located in ", "located-in"),
    (" is based in ", "based-in"),
    (" is situated in ", "situated-in"),
    // temporal (founding lineage treated as hierarchy per §2.2)
    (" was founded under ", "founded-under"),
    (" was established under ", "founded-under"),
    (" was created under ", "founded-under"),
];

/// Parent-side dependency cues: `<parent> CUE <child>`.
const PARENT_CUES: &[(&str, &str)] = &[
    // inclusion
    (" contains ", "contains"),
    (" contain ", "contains"),
    (" includes ", "includes"),
    (" include ", "includes"),
    (" comprises ", "comprises"),
    (" is composed of ", "composed-of"),
    (" consists of ", "consists-of"),
    (" encompasses ", "encompasses"),
    (" houses ", "houses"),
    (" hosts ", "hosts"),
    // functional / organizational
    (" oversees ", "oversees"),
    (" supervises ", "supervises"),
    (" manages ", "manages"),
    (" administers ", "administers"),
    (" governs ", "governs"),
    (" coordinates ", "coordinates"),
    // attribute (possession implies hierarchy in org charts)
    (" is responsible for ", "responsible-for"),
];

/// Split a conjunction group ("a, b and c") into its member phrases.
fn split_conjuncts(phrase: &str) -> Vec<String> {
    phrase
        .replace(" as well as ", " and ")
        .split(" and ")
        .flat_map(|part| part.split(',').map(str::to_string).collect::<Vec<_>>())
        .map(|s| clean_phrase(&s))
        .filter(|s| !s.is_empty())
        .collect()
}

/// Lowercase + collapse whitespace, *keeping* commas (pattern matching
/// needs them for appositives and conjunct lists; `clean_phrase` strips
/// them from the final entity names).
fn light_lower(sentence: &str) -> String {
    sentence
        .to_lowercase()
        .split_whitespace()
        .collect::<Vec<_>>()
        .join(" ")
}

/// Extract relations from one sentence.
fn extract_sentence(sentence: &str) -> Vec<Relation> {
    let s = format!(" {} ", light_lower(sentence));
    let mut out = Vec::new();

    for &(cue, rule) in CHILD_CUES {
        if let Some(pos) = s.find(cue) {
            let (lhs, rhs) = (&s[..pos], &s[pos + cue.len()..]);
            // conjunctions on the child side group under the same parent
            let parent = first_phrase(rhs);
            for child in split_conjuncts(lhs) {
                out.extend(Relation::new(&child, &parent, rule));
            }
        }
    }
    for &(cue, rule) in PARENT_CUES {
        if let Some(pos) = s.find(cue) {
            let (lhs, rhs) = (&s[..pos], &s[pos + cue.len()..]);
            let parent = normalize(lhs);
            for child in split_conjuncts(rhs) {
                out.extend(Relation::new(&child, &parent, rule));
            }
        }
    }

    // Appositive: "X, a unit/department/division/branch of Y"
    for marker in ["a unit of", "a department of", "a division of", "a branch of", "a part of"] {
        let pat = format!(", {marker} ");
        if let Some(pos) = s.find(&pat) {
            let child = &s[..pos];
            let parent = first_phrase(&s[pos + pat.len()..]);
            out.extend(Relation::new(child, &parent, "appositive"));
        }
    }
    out
}

/// First noun-phrase-ish chunk of a right-hand side: stop at conjunction,
/// comma or relative clause so "belongs to X and was founded" doesn't
/// swallow the rest of the sentence.
fn first_phrase(rhs: &str) -> String {
    let trimmed = rhs.trim();
    let end = trimmed
        .find(" and ")
        .or_else(|| trimmed.find(','))
        .or_else(|| trimmed.find(" which "))
        .or_else(|| trimmed.find(" that "))
        .unwrap_or(trimmed.len());
    trimmed[..end].to_string()
}

/// Extract hierarchical relations from a whole document.
pub fn extract(text: &str) -> Vec<Relation> {
    sentences(text)
        .iter()
        .flat_map(|s| extract_sentence(s))
        .collect()
}

/// Convenience: extraction to plain (child, parent) name pairs.
pub fn extract_pairs(text: &str) -> Vec<(String, String)> {
    extract(text)
        .into_iter()
        .map(|r| (r.child, r.parent))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn belongs_to() {
        let r = extract("The cardiology ward belongs to Mercy Hospital.");
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].child, "cardiology ward");
        assert_eq!(r[0].parent, "mercy hospital");
    }

    #[test]
    fn contains_reverses_direction() {
        let r = extract("Mercy Hospital contains the surgery center.");
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].child, "surgery center");
        assert_eq!(r[0].parent, "mercy hospital");
    }

    #[test]
    fn conjunction_groups_children() {
        let r = extract("The ICU and the burn unit belong to the surgery center.");
        assert_eq!(r.len(), 2);
        assert!(r.iter().all(|x| x.parent == "surgery center"));
        let children: Vec<&str> = r.iter().map(|x| x.child.as_str()).collect();
        assert!(children.contains(&"icu"));
        assert!(children.contains(&"burn unit"));
    }

    #[test]
    fn comma_conjunction_on_parent_side() {
        let r = extract("The faculty includes radiology, oncology and pediatrics.");
        assert_eq!(r.len(), 3);
        assert!(r.iter().all(|x| x.parent == "faculty"));
    }

    #[test]
    fn appositive() {
        let r = extract("The blood bank, a unit of the pathology lab, opened in 1990.");
        assert!(r.iter().any(|x| x.child == "blood bank" && x.parent == "pathology lab"),
            "{r:?}");
    }

    #[test]
    fn parent_phrase_stops_at_clause() {
        let r = extract("The pharmacy belongs to the hospital which was founded in 1900.");
        assert_eq!(r[0].parent, "hospital");
    }

    #[test]
    fn multiple_sentences() {
        let r = extract(
            "The ICU belongs to cardiology. Cardiology is part of Mercy Hospital.",
        );
        assert_eq!(r.len(), 2);
    }

    #[test]
    fn no_relation_no_output() {
        assert!(extract("The hospital opened in 1950 with ten beds.").is_empty());
    }

    #[test]
    fn dependent_on() {
        let r = extract("The dialysis unit is dependent on the nephrology service.");
        assert_eq!(r[0].child, "dialysis unit");
        assert_eq!(r[0].parent, "nephrology service");
    }

    #[test]
    fn geographic_located_in() {
        let r = extract("The burn center is located in the west wing.");
        assert_eq!(r[0].child, "burn center");
        assert_eq!(r[0].parent, "west wing");
        assert_eq!(r[0].rule, "located-in");
    }

    #[test]
    fn temporal_founded_under() {
        let r = extract("The imaging suite was founded under the radiology board.");
        assert_eq!(r[0].child, "imaging suite");
        assert_eq!(r[0].parent, "radiology board");
    }

    #[test]
    fn functional_operated_by() {
        let r = extract("The helipad is operated by the emergency service.");
        assert_eq!(r[0].child, "helipad");
        assert_eq!(r[0].parent, "emergency service");
    }

    #[test]
    fn attribute_responsible_for() {
        let r = extract("The pathology lab is responsible for the blood bank and the morgue.");
        assert_eq!(r.len(), 2);
        assert!(r.iter().all(|x| x.parent == "pathology lab"));
    }

    #[test]
    fn parent_side_houses_hosts() {
        let r = extract("The annex houses the archive. The campus hosts the clinic.");
        assert_eq!(r.len(), 2);
        assert_eq!(r[0].child, "archive");
        assert_eq!(r[1].child, "clinic");
    }

    #[test]
    fn subsidiary_of() {
        let r = extract("Lakeside Imaging is a subsidiary of Granite Health.");
        assert_eq!(r[0].child, "lakeside imaging");
        assert_eq!(r[0].parent, "granite health");
    }
}
