//! Deterministic concurrency model checking (`--features modelcheck`).
//!
//! A loom/shuttle-style checker, dependency-free like the rest of the
//! crate: the drop-in primitives in [`crate::sync`] route every sync
//! operation through a seeded cooperative scheduler
//! ([`scheduler`]), so a multi-threaded test body becomes a
//! *deterministic function of a seed*. [`explore`] runs the body under
//! many seeds (each a different interleaving, with PCT-style random
//! preemptions); a failing schedule panics with the seed that produced
//! it, and re-running with that seed replays the exact interleaving:
//!
//! ```text
//! MODELCHECK_SEED=12345 cargo test --features modelcheck -p cft-rag <test>
//! ```
//!
//! What counts as a failure:
//! * an assertion/panic anywhere in the model body or its vthreads,
//! * a deadlock — every vthread parked with no timeout to fire
//!   (reported with each vthread's name and what it waits on),
//! * a livelock — the schedule exceeds [`Config::max_steps`].
//!
//! Timeouts (`sleep`, `recv_timeout`, bounded submit waits) use
//! **virtual time**: a timeout only fires when no vthread can run, so
//! schedules are instant regardless of wall-clock durations and a
//! 5-second production timeout costs nothing to model.
//!
//! See `docs/TESTING.md` for where this sits in the verification
//! pyramid, and `tests/modelcheck_schedules.rs` for the schedule suite
//! covering the historical bug classes (PR-1 migration entry loss,
//! PR-2 generation/maintenance races, batcher submit-vs-stop).

#![warn(missing_debug_implementations)]

mod scheduler;

pub(crate) use scheduler::{managed, Shared, RES_SLEEP};

/// Exploration parameters. `Default` is sized for the in-tree schedule
/// suite: 64 seeds, 3 forced preemptions per schedule.
#[derive(Clone, Debug)]
pub struct Config {
    /// How many seeds (schedules) [`explore`] tries.
    pub iterations: u64,
    /// PCT depth: forced demotions of the running vthread per schedule.
    /// Depth *d* catches bugs needing *d* "unlucky" preemptions.
    pub preemption_depth: u32,
    /// Step range the preemption points are sampled from. Keep within
    /// the same order of magnitude as the schedule's real step count so
    /// the forced preemptions actually land inside the run.
    pub change_window: u64,
    /// Abort threshold: a schedule still running after this many sync
    /// steps is reported as a livelock.
    pub max_steps: u64,
    /// Base seed; per-iteration seeds derive from it deterministically.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            iterations: 64,
            preemption_depth: 3,
            change_window: 512,
            max_steps: 200_000,
            seed: 0xCF7_4A61,
        }
    }
}

/// A failing schedule: the seed to replay plus the report (panic
/// message, or the deadlock/livelock description).
#[derive(Clone, Debug)]
pub struct Failure {
    /// Seed that produced the failing interleaving.
    pub seed: u64,
    /// What went wrong under that schedule.
    pub report: String,
}

impl std::fmt::Display for Failure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "seed {}: {}", self.seed, self.report)
    }
}

/// Run `body` under exactly one seed. The deterministic replay entry
/// point: same seed, same interleaving, same outcome.
pub fn run_one(cfg: &Config, seed: u64, body: impl Fn()) -> Result<(), Failure> {
    scheduler::run(cfg, seed, &body).map_err(|report| Failure { seed, report })
}

/// Like [`explore`], but returns the first failure instead of
/// panicking (for tests asserting that the checker *catches* a bug).
/// `Ok(n)` reports how many schedules ran clean.
pub fn try_explore(
    cfg: &Config,
    body: impl Fn(),
) -> Result<u64, Failure> {
    if let Ok(v) = std::env::var("MODELCHECK_SEED") {
        let seed: u64 = v
            .parse()
            .unwrap_or_else(|_| panic!("MODELCHECK_SEED={v:?} is not a u64"));
        run_one(cfg, seed, body)?;
        return Ok(1);
    }
    let iterations = std::env::var("MODELCHECK_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(cfg.iterations);
    let mut stream = cfg.seed;
    for _ in 0..iterations {
        let seed = crate::util::rng::splitmix64(&mut stream);
        run_one(cfg, seed, &body)?;
    }
    Ok(iterations)
}

/// Explore `cfg.iterations` schedules of `body` (`name` labels the
/// failure report). Panics on the first failing schedule with the seed
/// and the exact command line that replays it. Honors two env vars:
/// `MODELCHECK_SEED` (replay a single seed) and `MODELCHECK_ITERS`
/// (override the iteration count, e.g. a deeper nightly run).
pub fn explore(name: &str, cfg: &Config, body: impl Fn()) {
    if let Err(f) = try_explore(cfg, body) {
        panic!(
            "[{name}] schedule failed under seed {}:\n{}\n\
             replay: MODELCHECK_SEED={} cargo test --features modelcheck \
             -p cft-rag {name}",
            f.seed, f.report, f.seed
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sync::atomic::{AtomicU32, Ordering::SeqCst};
    use crate::sync::mpsc::{channel, sync_channel, RecvTimeoutError};
    use crate::sync::{thread, Arc, Mutex};
    use std::time::Duration;

    fn quick(iterations: u64, window: u64) -> Config {
        Config {
            iterations,
            change_window: window,
            max_steps: 20_000,
            ..Config::default()
        }
    }

    /// Self-lock is a deadlock under every schedule: the detector must
    /// fire on the very first seed and name the parked resource.
    #[test]
    fn detects_self_deadlock_deterministically() {
        let f = try_explore(&quick(1, 16), || {
            let m = Mutex::new(0u32);
            let _g1 = m.lock().unwrap();
            let _g2 = m.lock().unwrap(); // never acquirable
        })
        .expect_err("self-lock must deadlock");
        assert!(f.report.contains("deadlock"), "report: {}", f.report);
        assert!(f.report.contains("mutex"), "report: {}", f.report);
    }

    /// The classic ABBA deadlock, forced by a channel handshake so
    /// *every* schedule reaches the cycle: both vthreads hold one lock
    /// before either asks for the second.
    #[test]
    fn detects_lock_order_inversion() {
        let f = try_explore(&quick(2, 64), || {
            let a = Arc::new(Mutex::new(()));
            let b = Arc::new(Mutex::new(()));
            let (ready_tx, ready_rx) = channel::<()>();
            let (go_tx, go_rx) = channel::<()>();
            let worker = {
                let (a, b) = (Arc::clone(&a), Arc::clone(&b));
                thread::spawn(move || {
                    let _ga = a.lock().unwrap();
                    ready_tx.send(()).unwrap();
                    go_rx.recv().unwrap();
                    let _gb = b.lock().unwrap(); // A then B
                })
            };
            ready_rx.recv().unwrap();
            let _gb = b.lock().unwrap();
            go_tx.send(()).unwrap();
            let _ga = a.lock().unwrap(); // B then A
            drop(_ga);
            drop(_gb);
            worker.join().unwrap();
        })
        .expect_err("ABBA inversion must deadlock under every schedule");
        assert!(f.report.contains("deadlock"), "report: {}", f.report);
    }

    /// A load-then-store "increment" is not atomic; exploration must
    /// find the interleaving where one update is lost. This is the
    /// checker's own canary: if preemption sampling regresses, this
    /// test stops failing-in-the-model and starts failing-for-real.
    #[test]
    fn finds_lost_update_interleaving() {
        let f = try_explore(&quick(512, 24), || {
            let n = Arc::new(AtomicU32::new(0));
            let workers: Vec<_> = (0..2)
                .map(|_| {
                    let n = Arc::clone(&n);
                    thread::spawn(move || {
                        let v = n.load(SeqCst);
                        n.store(v + 1, SeqCst); // racy read-modify-write
                    })
                })
                .collect();
            for w in workers {
                w.join().unwrap();
            }
            assert_eq!(n.load(SeqCst), 2, "an increment was lost");
        })
        .expect_err("the lost-update schedule must be found");
        assert!(f.report.contains("increment was lost"), "{}", f.report);
    }

    /// Replaying the failing seed reproduces the identical failure —
    /// the contract the printed `MODELCHECK_SEED=` line relies on.
    #[test]
    fn failing_seed_replays_identically() {
        let cfg = quick(512, 24);
        let body = || {
            let n = Arc::new(AtomicU32::new(0));
            let workers: Vec<_> = (0..2)
                .map(|_| {
                    let n = Arc::clone(&n);
                    thread::spawn(move || {
                        let v = n.load(SeqCst);
                        n.store(v + 1, SeqCst);
                    })
                })
                .collect();
            for w in workers {
                w.join().unwrap();
            }
            assert_eq!(n.load(SeqCst), 2, "an increment was lost");
        };
        let first = try_explore(&cfg, body).expect_err("must fail");
        let again =
            run_one(&cfg, first.seed, body).expect_err("replay must fail");
        assert_eq!(first.seed, again.seed);
        assert_eq!(first.report, again.report, "replay diverged");
    }

    /// Virtual time: sleeps complete in deadline order, not spawn or
    /// priority order, and cost no wall-clock time.
    #[test]
    fn virtual_time_orders_sleeps_by_deadline() {
        explore("virtual_time_orders_sleeps_by_deadline", &quick(16, 64), || {
            // std Mutex on purpose: bookkeeping the scheduler must not
            // see (no yield points inside the critical section).
            let log = Arc::new(std::sync::Mutex::new(Vec::new()));
            let slow = {
                let log = Arc::clone(&log);
                thread::spawn(move || {
                    thread::sleep(Duration::from_millis(50));
                    log.lock().unwrap().push("slow");
                })
            };
            let fast = {
                let log = Arc::clone(&log);
                thread::spawn(move || {
                    thread::sleep(Duration::from_millis(1));
                    log.lock().unwrap().push("fast");
                })
            };
            fast.join().unwrap();
            slow.join().unwrap();
            assert_eq!(*log.lock().unwrap(), vec!["fast", "slow"]);
        });
    }

    /// Bounded channels: FIFO order survives every schedule, a full
    /// queue blocks the sender until the consumer drains, and
    /// `recv_timeout` distinguishes Timeout from Disconnected.
    #[test]
    fn bounded_channel_semantics_hold_under_all_schedules() {
        explore(
            "bounded_channel_semantics_hold_under_all_schedules",
            &quick(32, 128),
            || {
                let (tx, rx) = sync_channel::<u32>(1);
                let producer = thread::spawn(move || {
                    for i in 0..4 {
                        tx.send(i).unwrap(); // blocks while full
                    }
                });
                let mut got = Vec::new();
                for _ in 0..4 {
                    got.push(rx.recv().unwrap());
                }
                producer.join().unwrap();
                assert_eq!(got, vec![0, 1, 2, 3]);
                // all senders gone -> Disconnected, not Timeout
                assert!(matches!(
                    rx.recv_timeout(Duration::from_millis(5)),
                    Err(RecvTimeoutError::Disconnected)
                ));

                // a live-but-slow sender -> Timeout at the virtual
                // deadline (instant in wall-clock terms)
                let (tx2, rx2) = sync_channel::<u32>(1);
                let late = thread::spawn(move || {
                    thread::sleep(Duration::from_millis(60));
                    let _ = tx2.send(7);
                });
                assert!(matches!(
                    rx2.recv_timeout(Duration::from_millis(5)),
                    Err(RecvTimeoutError::Timeout)
                ));
                assert_eq!(rx2.recv().unwrap(), 7);
                late.join().unwrap();
            },
        );
    }
}
