//! Pipeline configuration.

use std::time::Duration;

use crate::error::{CftError, Result};
use crate::filter::cuckoo::CuckooConfig;
use crate::router::ring::ShardRing;

/// Which retrieval algorithm backs the pipeline (paper §4.1–4.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// Naive T-RAG: BFS every tree.
    Naive,
    /// Bloom Filter T-RAG.
    Bloom,
    /// Improved Bloom Filter T-RAG (skip near-leaf checks).
    Bloom2,
    /// Cuckoo Filter T-RAG (the paper's system).
    Cuckoo,
}

impl Algorithm {
    /// All four, in the paper's table order.
    pub const ALL: [Algorithm; 4] =
        [Algorithm::Naive, Algorithm::Bloom, Algorithm::Bloom2, Algorithm::Cuckoo];

    /// Table label.
    pub fn label(&self) -> &'static str {
        match self {
            Algorithm::Naive => "Naive T-RAG",
            Algorithm::Bloom => "BF T-RAG",
            Algorithm::Bloom2 => "BF2 T-RAG",
            Algorithm::Cuckoo => "CF T-RAG",
        }
    }

    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Option<Algorithm> {
        match s.to_lowercase().as_str() {
            "naive" => Some(Algorithm::Naive),
            "bloom" | "bf" => Some(Algorithm::Bloom),
            "bloom2" | "bf2" => Some(Algorithm::Bloom2),
            "cuckoo" | "cf" => Some(Algorithm::Cuckoo),
            _ => None,
        }
    }
}

/// Key-partition membership of one serving backend in an R-way
/// replicated fleet: which slice of the entity-key space this backend
/// must index.
///
/// Built over the **same address list** (same strings, same order) that
/// the router's [`ShardRing`] fronts — the partition embeds its own ring
/// so that "the keys backend `i` owns" is computed with exactly the
/// rendezvous ranking the router routes by. A key belongs to the
/// backends in `ring.replicas(key, replicas)`; everything else is
/// skipped at index-build time, cutting per-backend filter/annotation
/// memory to roughly `R/N` of a full index.
///
/// **Partition epoch**: every membership change of the fleet (a backend
/// joining or draining, `router/rebalance.rs`) bumps the fleet-wide
/// epoch. A backend reports its partition's epoch in the `\x01stats`
/// payload, and the router's health prober refuses to (re-)admit a
/// backend whose reported epoch does not match the serving ring's — a
/// backend mid-warm-up or running a stale partition must not attract
/// traffic. `new` starts at epoch 0 (fleet start); the `\x01repartition`
/// control line installs later epochs.
///
/// **Warming**: a backend started to *join* a running fleet
/// ([`KeyPartition::joining`], `cft-rag serve --joining`) builds an
/// **empty** index — its keys arrive exclusively through the router's
/// warm-up handoff (`\x01insert` replay from the current replicas), so
/// the joiner's index reflects the fleet's live state, including every
/// dynamic update since fleet start, rather than a possibly stale
/// forest snapshot. Dynamic updates are accepted for owned keys
/// throughout (that is what the handoff rides on).
#[derive(Clone, Debug)]
pub struct KeyPartition {
    ring: ShardRing,
    backend_index: usize,
    replicas: usize,
    /// Fleet-wide membership epoch this partition belongs to.
    epoch: u64,
    /// True while the backend awaits its warm-up handoff: nothing is
    /// indexed at build time.
    warming: bool,
}

impl KeyPartition {
    /// Partition for backend `backend_index` of `backends`, replicating
    /// every key across its top-`replicas` ranked backends. Errors on an
    /// empty fleet, an out-of-range index, or `replicas` outside
    /// `1..=backends.len()`. Starts at epoch 0, not warming.
    pub fn new<S: Into<String>>(
        backends: impl IntoIterator<Item = S>,
        backend_index: usize,
        replicas: usize,
    ) -> Result<KeyPartition> {
        let ring = ShardRing::new(backends);
        if ring.is_empty() {
            return Err(CftError::Config(
                "key partition needs at least one backend".into(),
            ));
        }
        if backend_index >= ring.len() {
            return Err(CftError::Config(format!(
                "backend index {backend_index} out of range ({} backends)",
                ring.len()
            )));
        }
        if replicas == 0 || replicas > ring.len() {
            return Err(CftError::Config(format!(
                "replication factor {replicas} outside 1..={}",
                ring.len()
            )));
        }
        Ok(KeyPartition {
            ring,
            backend_index,
            replicas,
            epoch: 0,
            warming: false,
        })
    }

    /// The same partition at a given fleet epoch (builder-style).
    pub fn with_epoch(mut self, epoch: u64) -> KeyPartition {
        self.epoch = epoch;
        self
    }

    /// Partition for a backend **joining** a running fleet: identical
    /// ownership, but [`index_at_build`](KeyPartition::index_at_build)
    /// is false for every key, so the index starts empty and is filled
    /// by the router's warm-up handoff.
    pub fn joining<S: Into<String>>(
        backends: impl IntoIterator<Item = S>,
        backend_index: usize,
        replicas: usize,
    ) -> Result<KeyPartition> {
        let mut p = KeyPartition::new(backends, backend_index, replicas)?;
        p.warming = true;
        Ok(p)
    }

    /// True when `key`'s replica set contains this backend — i.e. this
    /// backend must index (and accept dynamic updates for) the key.
    pub fn owns(&self, key: u64) -> bool {
        self.ring.replicas(key, self.replicas).contains(&self.backend_index)
    }

    /// True when `key` should be indexed at **build time**: owned, and
    /// the backend is not warming (a joining backend's keys arrive via
    /// handoff instead).
    pub fn index_at_build(&self, key: u64) -> bool {
        !self.warming && self.owns(key)
    }

    /// This backend's position in the fleet's address list.
    pub fn backend_index(&self) -> usize {
        self.backend_index
    }

    /// The replication factor R the partition was built for.
    pub fn replicas(&self) -> usize {
        self.replicas
    }

    /// Number of backends in the fleet.
    pub fn num_backends(&self) -> usize {
        self.ring.len()
    }

    /// The fleet membership epoch this partition was built for
    /// (reported as `partition_epoch` in the `\x01stats` payload).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// True while the backend awaits its warm-up handoff.
    pub fn is_warming(&self) -> bool {
        self.warming
    }

    /// The fleet address list this partition hashes (ring order).
    pub fn addresses(&self) -> Vec<String> {
        (0..self.ring.len())
            .map(|i| self.ring.name(i).to_string())
            .collect()
    }
}

/// End-to-end pipeline configuration.
#[derive(Clone, Debug)]
pub struct RagConfig {
    /// Retrieval algorithm.
    pub algorithm: Algorithm,
    /// Hierarchy levels captured up/down in context (paper's n).
    pub context_levels: usize,
    /// Documents fetched by the vector-search stage.
    pub topk_docs: usize,
    /// Bloom baselines: per-node filter FP rate.
    pub bloom_fp_rate: f64,
    /// Cuckoo filter tuning. Of serving interest:
    /// `cuckoo.migration_step_buckets` bounds how long a shard write
    /// lock is held while the filter doubles under load — smaller steps
    /// mean tighter reader tail latency during growth; `0` opts back
    /// into the monolithic single-hold migration (bench comparison arm).
    pub cuckoo: CuckooConfig,
    /// Cuckoo filter shards (rounded up to a power of two). On the
    /// concurrent serving path (`make_concurrent_retriever`), `0` =
    /// auto (one shard per available core). The single-threaded
    /// `make_retriever` has no parallelism to win, so there `0` and `1`
    /// both select the classic unsharded filter (whose probe statistics
    /// the Figure-5 bench reads); only `shards > 1` shards it. Ignored
    /// by the non-Cuckoo baselines.
    pub shards: usize,
    /// R-way replication factor of the fleet this backend belongs to
    /// (how many backends index each entity key). Only meaningful
    /// together with [`key_partition`](RagConfig::key_partition) — a
    /// standalone backend (partition `None`) indexes everything
    /// regardless. Must match the partition's own factor; the
    /// coordinator validates this at startup.
    pub replication_factor: usize,
    /// When set, the Cuckoo retrievers index **only** the keys whose
    /// replica set contains this backend (enforced at index-build time
    /// in `make_retriever`/`make_concurrent_retriever`, and on every
    /// dynamic insert/delete thereafter). `None` = full index (single
    /// node, or the pre-replication full-index fleet).
    pub key_partition: Option<KeyPartition>,
    /// Front-door connection cap of this backend's TCP listener
    /// (`coordinator/tcp.rs`): connections past it get a one-line
    /// `{"ok":false,"error":"overloaded"}` refusal instead of
    /// accepting until fd exhaustion. `0` = unlimited. See
    /// `docs/OPERATIONS.md`, "Connection limits and timeouts".
    pub max_connections: usize,
    /// Reap a front-door connection this long after its last
    /// *completed* request line (dribbled partial lines do not refresh
    /// the clock, so slowloris clients are reaped on schedule). Zero
    /// disables the reaper.
    pub idle_timeout: Duration,
    /// Head-sampling period of the request tracer (`obs/trace.rs`):
    /// every Nth front-door request gets a trace id minted and its
    /// stage spans recorded. `0` (default) disables head sampling —
    /// tracing then costs one branch per stage. Slow queries (see
    /// [`slow_query_threshold`](RagConfig::slow_query_threshold)) are
    /// surfaced regardless of the sampling decision.
    pub trace_sample_every: u64,
    /// A request slower than this (front-door wall time) is always
    /// recorded in the recent-traces ring and logged as a structured
    /// `slow_query` line, even when head sampling skipped it. Zero
    /// disables slow-query capture.
    pub slow_query_threshold: Duration,
    /// When set, the coordinator persists its dynamic-update stream
    /// here (`persist/`): acked `\x01insert`/`\x01delete` ops go to an
    /// append-only log, snapshots to `snapshot.cft`, and startup
    /// recovers snapshot + log replay so a killed backend restarts warm
    /// (`--data-dir`). `None` (default) = volatile, the pre-durability
    /// behaviour.
    pub data_dir: Option<std::path::PathBuf>,
    /// fsync the op log after every N acked ops (`--fsync-every`). `1`
    /// (default) is the strict ack-after-durable guarantee the crash
    /// harness proves; `N > 1` batches fsyncs, trading up to N-1 acked
    /// writes on power loss for throughput. Ignored without
    /// [`data_dir`](RagConfig::data_dir); must be ≥ 1.
    pub fsync_every: u32,
    /// Cut a snapshot automatically after this many acked ops
    /// (`--snapshot-interval-ops`), folding the log into `snapshot.cft`
    /// and truncating it. `0` (default) = only on `\x01snapshot` or
    /// graceful shutdown. Ignored without [`data_dir`](RagConfig::data_dir).
    pub snapshot_interval_ops: u64,
    /// Backend-side per-entity context cache
    /// (`retrieval/context_cache.rs`): memoize each hot entity's
    /// generated [`Context`](crate::retrieval::context::Context) so a
    /// repeat mention skips the filter walk and tree traversal
    /// entirely. Entries, not bytes — contexts are small and uniform.
    /// Invalidated per-entity on applied `\x01insert`/`\x01delete` and
    /// wholesale on `\x01repartition`/purge, under the same
    /// never-stale contract as the router's reply cache. `0`
    /// (default) = off; the `cft-rag serve` CLI enables it
    /// (`--context-cache`).
    pub context_cache_entries: usize,
}

impl Default for RagConfig {
    fn default() -> Self {
        RagConfig {
            algorithm: Algorithm::Cuckoo,
            context_levels: 3,
            topk_docs: 3,
            bloom_fp_rate: 0.01,
            cuckoo: CuckooConfig::default(),
            shards: 0,
            replication_factor: 1,
            key_partition: None,
            max_connections: 4096,
            idle_timeout: Duration::from_secs(60),
            trace_sample_every: 0,
            slow_query_threshold: Duration::from_millis(250),
            data_dir: None,
            fsync_every: 1,
            snapshot_interval_ops: 0,
            context_cache_entries: 0,
        }
    }
}

impl RagConfig {
    /// Resolve the configured shard count: `0` maps to the number of
    /// available cores (so coordinator read throughput scales with the
    /// worker pool by default), anything else passes through.
    pub fn resolved_shards(&self) -> usize {
        if self.shards == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            self.shards
        }
    }

    /// Build this backend's [`KeyPartition`] for position `backend_index`
    /// in `backends`, using the configured replication factor.
    pub fn partition_for<S: Into<String>>(
        &self,
        backends: impl IntoIterator<Item = S>,
        backend_index: usize,
    ) -> Result<KeyPartition> {
        KeyPartition::new(
            backends,
            backend_index,
            self.replication_factor.max(1),
        )
    }

    /// Validate the partition/replication knobs (the coordinator calls
    /// this at startup so a mis-deployed backend fails fast instead of
    /// silently serving the wrong slice of the key space).
    pub fn validate(&self) -> Result<()> {
        if let Some(p) = &self.key_partition {
            if self.algorithm != Algorithm::Cuckoo {
                return Err(CftError::Config(format!(
                    "key-partitioned indexes require the Cuckoo retriever \
                     (got {}): the Bloom/naive baselines annotate whole \
                     trees and cannot skip per-key",
                    self.algorithm.label()
                )));
            }
            if p.replicas() != self.replication_factor.max(1) {
                return Err(CftError::Config(format!(
                    "key partition was built for R={} but \
                     replication_factor is {}",
                    p.replicas(),
                    self.replication_factor
                )));
            }
        }
        if self.fsync_every == 0 {
            return Err(CftError::Config(
                "fsync_every must be >= 1 (1 = fsync per acked op; \
                 N > 1 batches durability)"
                    .to_string(),
            ));
        }
        Ok(())
    }
}

/// Configuration of the distributed shard router (`router/`): which
/// coordinator backends to front, and the timeouts/health policy of the
/// scatter-gather query path. See `router/mod.rs` for the topology.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Backend addresses (`host:port`), each a TCP coordinator speaking
    /// the newline-delimited JSON protocol of `coordinator/tcp.rs`.
    /// Order matters only for deterministic tie-breaks in the ring.
    pub backends: Vec<String>,
    /// TCP connect timeout per backend attempt.
    pub connect_timeout: Duration,
    /// **End-to-end per-request deadline**: connect + write + the full
    /// reply, enforced by the outbound reactor
    /// (`reactor/client.rs::NetDriver`) as an absolute deadline rather
    /// than per-stream socket timeouts — a backend dribbling one byte
    /// per read-timeout cannot stretch the budget. One slow backend
    /// degrades its portion of a fanned-out reply instead of stalling
    /// the whole merge.
    pub request_timeout: Duration,
    /// Active health-probe period (`\x01stats` round trip per backend);
    /// zero disables the prober thread (tests that want deterministic
    /// backend traffic, or ops setups with external health checking).
    pub probe_interval: Duration,
    /// Consecutive request failures before a backend is passively
    /// marked unhealthy (probes re-admit it on the next success).
    pub failure_threshold: u32,
    /// Backends tried per sub-request before giving up: the owner
    /// first, then the ring's failover order.
    pub max_attempts: usize,
    /// Idle pooled connections kept per backend.
    pub max_idle_conns: usize,
    /// R-way replication of the fleet's indexes. `0` (default) means
    /// the backends are **full indexes** — any backend can serve any
    /// key, reads walk the whole ring on failover, and writes broadcast
    /// to every backend. `R >= 1` means the backends were started with
    /// a matching [`KeyPartition`]: only a key's top-R ranked backends
    /// hold it, so reads are served from the least-loaded healthy
    /// replica (ranked failover stays **within** the replica set — a
    /// non-replica would answer with silently missing facts) and writes
    /// fan out to all R replicas.
    pub replication_factor: usize,
    /// Per-replica acks required before a broadcast write
    /// (`\x01insert`/`\x01delete`) reports `ok:true`. `0` (default)
    /// requires every targeted replica to ack; otherwise at least this
    /// many (clamped to the target count).
    pub write_quorum: usize,
    /// Router front-door connection cap (`router/mod.rs::serve`):
    /// connections past it get a one-line
    /// `{"ok":false,"error":"overloaded"}` refusal. `0` = unlimited.
    pub max_connections: usize,
    /// Reap a router front-door connection this long after its last
    /// completed request line. Zero disables the reaper.
    pub idle_timeout: Duration,
    /// Head-sampling period of the router's request tracer: every Nth
    /// front-door request is traced end to end (the minted id rides to
    /// the backends as a `\x01t=` line prefix). `0` (default) = off;
    /// slow queries are captured regardless.
    pub trace_sample_every: u64,
    /// A routed request slower than this is always recorded and logged
    /// as a `slow_query` line, sampled or not. Zero disables capture.
    pub slow_query_threshold: Duration,
    /// Reply-cache budget in approximate heap bytes
    /// (`router/cache.rs`): hot query replies are served straight from
    /// the router, invalidated per-entity on acked writes and
    /// wholesale on membership epoch rolls. `0` (default) disables the
    /// cache — the library default is off so embedding tests see
    /// unchanged routing behaviour; the `cft-rag route` CLI turns it
    /// on (8 MiB) unless `--cache-off`.
    pub cache_capacity_bytes: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            backends: Vec::new(),
            connect_timeout: Duration::from_secs(1),
            request_timeout: Duration::from_secs(5),
            probe_interval: Duration::from_millis(500),
            failure_threshold: 1,
            max_attempts: 3,
            max_idle_conns: 4,
            replication_factor: 0,
            write_quorum: 0,
            max_connections: 4096,
            idle_timeout: Duration::from_secs(60),
            trace_sample_every: 0,
            slow_query_threshold: Duration::from_millis(250),
            cache_capacity_bytes: 0,
        }
    }
}

impl RouterConfig {
    /// Convenience: a config fronting `backends` with default policy.
    pub fn for_backends<S: Into<String>>(
        backends: impl IntoIterator<Item = S>,
    ) -> Self {
        RouterConfig {
            backends: backends.into_iter().map(Into::into).collect(),
            ..RouterConfig::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_labels() {
        assert_eq!(Algorithm::parse("cf"), Some(Algorithm::Cuckoo));
        assert_eq!(Algorithm::parse("NAIVE"), Some(Algorithm::Naive));
        assert_eq!(Algorithm::parse("bf2"), Some(Algorithm::Bloom2));
        assert_eq!(Algorithm::parse("nope"), None);
    }

    #[test]
    fn labels_match_paper() {
        assert_eq!(Algorithm::Cuckoo.label(), "CF T-RAG");
        assert_eq!(Algorithm::ALL.len(), 4);
    }

    #[test]
    fn migration_step_knob_flows_through() {
        use crate::filter::cuckoo::CuckooFilter;
        use crate::filter::fingerprint::entity_key;

        let mut cfg = RagConfig::default();
        assert!(
            cfg.cuckoo.migration_step_buckets > 0,
            "serving config must default to incremental expansion"
        );
        // The knob must change actual filter behavior, not just sit in
        // the struct: with 1-bucket steps a threshold crossing leaves
        // the doubling observably in flight after an insert burst...
        cfg.cuckoo.initial_buckets = 64;
        cfg.cuckoo.migration_step_buckets = 1;
        let mut incremental = CuckooFilter::new(cfg.cuckoo);
        for i in 0..300u64 {
            incremental.insert(entity_key(&format!("knob-{i}")), &[]);
        }
        assert!(
            incremental.migration_pending(),
            "1-bucket steps leave the doubling in flight"
        );
        // ...while 0 (monolithic opt-out) completes inside the insert.
        cfg.cuckoo.migration_step_buckets = 0;
        let mut monolithic = CuckooFilter::new(cfg.cuckoo);
        for i in 0..300u64 {
            monolithic.insert(entity_key(&format!("knob-{i}")), &[]);
        }
        assert!(!monolithic.migration_pending(), "0 = whole-table migration");
    }

    #[test]
    fn router_config_defaults_sane() {
        let cfg = RouterConfig::default();
        assert!(cfg.backends.is_empty());
        assert!(cfg.max_attempts >= 1);
        assert!(cfg.failure_threshold >= 1);
        assert!(!cfg.request_timeout.is_zero());
        let cfg = RouterConfig::for_backends(["a:1", "b:2"]);
        assert_eq!(cfg.backends, vec!["a:1".to_string(), "b:2".to_string()]);
    }

    #[test]
    fn serving_knob_defaults_bound_both_front_doors() {
        // both front doors ship with a finite connection cap and a
        // nonzero idle reaper — an unbounded default would accept
        // until fd exhaustion and never reap a slowloris client
        let rag = RagConfig::default();
        assert!(rag.max_connections > 0);
        assert!(!rag.idle_timeout.is_zero());
        let router = RouterConfig::default();
        assert!(router.max_connections > 0);
        assert!(!router.idle_timeout.is_zero());
        // and the two doors agree, so a fleet behaves uniformly
        assert_eq!(rag.max_connections, router.max_connections);
        assert_eq!(rag.idle_timeout, router.idle_timeout);
        // tracing knobs: off-by-default head sampling, slow queries
        // always captured, and identical defaults across doors
        assert_eq!(rag.trace_sample_every, 0);
        assert!(!rag.slow_query_threshold.is_zero());
        assert_eq!(rag.trace_sample_every, router.trace_sample_every);
        assert_eq!(rag.slow_query_threshold, router.slow_query_threshold);
    }

    #[test]
    fn durability_knobs_default_volatile_and_strict() {
        let rag = RagConfig::default();
        assert!(rag.data_dir.is_none(), "persistence is opt-in");
        assert_eq!(rag.fsync_every, 1, "default is ack-after-durable");
        assert_eq!(rag.snapshot_interval_ops, 0, "no auto-snapshot");
        assert!(rag.validate().is_ok());
        let bad = RagConfig { fsync_every: 0, ..RagConfig::default() };
        assert!(bad.validate().is_err(), "fsync_every 0 must fail fast");
    }

    #[test]
    fn key_partition_validates_and_partitions() {
        use crate::filter::fingerprint::entity_key;

        assert!(KeyPartition::new(Vec::<String>::new(), 0, 1).is_err());
        assert!(KeyPartition::new(["a:1", "b:2"], 2, 1).is_err(), "index");
        assert!(KeyPartition::new(["a:1", "b:2"], 0, 0).is_err(), "R=0");
        assert!(KeyPartition::new(["a:1", "b:2"], 0, 3).is_err(), "R>N");

        // every key is owned by exactly R of the N partitions
        let backends = ["a:1", "b:2", "c:3", "d:4"];
        for r in 1..=backends.len() {
            let parts: Vec<KeyPartition> = (0..backends.len())
                .map(|i| KeyPartition::new(backends, i, r).unwrap())
                .collect();
            for name in ["cardiology", "oncology", "ward 3", "surgery"] {
                let key = entity_key(name);
                let holders =
                    parts.iter().filter(|p| p.owns(key)).count();
                assert_eq!(holders, r, "{name} at R={r}");
            }
        }
    }

    #[test]
    fn partition_epoch_and_warming() {
        use crate::filter::fingerprint::entity_key;

        let p = KeyPartition::new(["a:1", "b:2"], 0, 1).unwrap();
        assert_eq!(p.epoch(), 0, "fleet start is epoch 0");
        assert!(!p.is_warming());
        assert_eq!(p.with_epoch(3).epoch(), 3);

        // a joining partition owns its keys but indexes none at build
        let j = KeyPartition::joining(["a:1", "b:2"], 1, 2).unwrap();
        assert!(j.is_warming());
        for name in ["cardiology", "oncology", "ward 3"] {
            let key = entity_key(name);
            assert!(j.owns(key), "{name}: R=N partition owns everything");
            assert!(
                !j.index_at_build(key),
                "{name}: warming partitions build empty"
            );
        }
        assert_eq!(
            j.addresses(),
            vec!["a:1".to_string(), "b:2".to_string()],
            "address list round-trips in ring order"
        );
    }

    #[test]
    fn rag_config_validation_catches_mismatches() {
        let partition = KeyPartition::new(["a:1", "b:2", "c:3"], 1, 2).unwrap();
        assert_eq!(partition.backend_index(), 1);
        assert_eq!(partition.num_backends(), 3);

        let good = RagConfig {
            replication_factor: 2,
            key_partition: Some(partition.clone()),
            ..RagConfig::default()
        };
        good.validate().unwrap();

        let wrong_r = RagConfig {
            replication_factor: 3,
            key_partition: Some(partition.clone()),
            ..RagConfig::default()
        };
        assert!(wrong_r.validate().is_err(), "R mismatch must fail");

        let wrong_alg = RagConfig {
            algorithm: Algorithm::Bloom,
            replication_factor: 2,
            key_partition: Some(partition),
            ..RagConfig::default()
        };
        assert!(wrong_alg.validate().is_err(), "non-Cuckoo must fail");

        RagConfig::default().validate().unwrap();

        // partition_for wires the configured R through
        let cfg = RagConfig { replication_factor: 2, ..RagConfig::default() };
        let p = cfg.partition_for(["a:1", "b:2"], 0).unwrap();
        assert_eq!(p.replicas(), 2);
    }

    #[test]
    fn router_replication_defaults_to_full_index() {
        let cfg = RouterConfig::default();
        assert_eq!(cfg.replication_factor, 0, "0 = full-index backends");
        assert_eq!(cfg.write_quorum, 0, "0 = all replicas must ack");
    }

    #[test]
    fn cache_knobs_default_off_in_the_library() {
        // both caches are opt-in at the library layer so embedding
        // tests (and the pre-cache fleets they model) see byte-for-byte
        // unchanged behaviour; the CLI flips the defaults on
        assert_eq!(RouterConfig::default().cache_capacity_bytes, 0);
        assert_eq!(RagConfig::default().context_cache_entries, 0);
        assert!(RagConfig::default().validate().is_ok());
    }

    #[test]
    fn shards_resolve() {
        let auto = RagConfig::default();
        assert_eq!(auto.shards, 0, "default is auto");
        assert!(auto.resolved_shards() >= 1);
        let fixed = RagConfig { shards: 8, ..RagConfig::default() };
        assert_eq!(fixed.resolved_shards(), 8);
    }
}
