//! Integration tests over the REAL artifacts: load `artifacts/*.hlo.txt`
//! on the PJRT CPU client and verify the L1/L2 semantics from Rust.
//!
//! Skipped (with a notice) when artifacts are absent — run
//! `make artifacts` first; CI always runs them via the Makefile.

use cft_rag::runtime::{default_dir, Manifest, Runtime};
use cft_rag::text::tokenizer::tokenize_padded;

fn runtime() -> Option<Runtime> {
    let dir = default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        return None;
    }
    Some(Runtime::load(dir).expect("runtime must load when artifacts exist"))
}

#[test]
fn manifest_matches_python_constants() {
    let dir = default_dir();
    if !dir.join("manifest.json").exists() {
        return;
    }
    let m = Manifest::load(dir).unwrap();
    assert_eq!(m.batch, 8);
    assert_eq!(m.embed_dim, 64);
    assert_eq!(m.max_tokens, 32);
    assert_eq!(m.shard_docs, 1024);
    assert_eq!(m.max_facts, 64);
    assert_eq!(m.pad_id, 0);
}

#[test]
fn embed_artifact_unit_norm_and_deterministic() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest().clone();
    let mut tokens = vec![0i32; m.batch * m.max_tokens];
    tokens[..m.max_tokens]
        .copy_from_slice(&tokenize_padded("cardiology intensive care", m.max_tokens));
    tokens[m.max_tokens..2 * m.max_tokens]
        .copy_from_slice(&tokenize_padded("surgery theatre", m.max_tokens));

    let a = rt.embed(&tokens).unwrap();
    let b = rt.embed(&tokens).unwrap();
    assert_eq!(a, b, "deterministic");
    for row in 0..2 {
        let v = &a[row * m.embed_dim..(row + 1) * m.embed_dim];
        let norm: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-3, "row {row} norm {norm}");
    }
}

#[test]
fn embed_artifact_similarity_tracks_token_overlap() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest().clone();
    let mut tokens = vec![0i32; m.batch * m.max_tokens];
    let texts = [
        "cardiology intensive care unit",
        "cardiology intensive care ward",
        "logistics warehouse supply office",
    ];
    for (i, t) in texts.iter().enumerate() {
        tokens[i * m.max_tokens..(i + 1) * m.max_tokens]
            .copy_from_slice(&tokenize_padded(t, m.max_tokens));
    }
    let e = rt.embed(&tokens).unwrap();
    let dot = |a: usize, b: usize| -> f32 {
        e[a * m.embed_dim..(a + 1) * m.embed_dim]
            .iter()
            .zip(&e[b * m.embed_dim..(b + 1) * m.embed_dim])
            .map(|(x, y)| x * y)
            .sum()
    };
    assert!(
        dot(0, 1) > dot(0, 2) + 0.1,
        "similar {} vs dissimilar {}",
        dot(0, 1),
        dot(0, 2)
    );
}

#[test]
fn score_artifact_finds_self() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest().clone();
    // docs: deterministic unit vectors
    let mut docs = vec![0f32; m.shard_docs * m.embed_dim];
    for i in 0..m.shard_docs {
        let mut norm = 0f32;
        for d in 0..m.embed_dim {
            let v = ((i * 31 + d * 7 + 3) as f32).sin();
            docs[i * m.embed_dim + d] = v;
            norm += v * v;
        }
        let norm = norm.sqrt();
        for d in 0..m.embed_dim {
            docs[i * m.embed_dim + d] /= norm;
        }
    }
    // queries = rows 5, 100, 1023, ...
    let picks = [5usize, 100, 1023, 0, 512, 7, 9, 300];
    let mut q = vec![0f32; m.batch * m.embed_dim];
    for (b, &i) in picks.iter().enumerate() {
        q[b * m.embed_dim..(b + 1) * m.embed_dim]
            .copy_from_slice(&docs[i * m.embed_dim..(i + 1) * m.embed_dim]);
    }
    let scores = rt.score(&q, &docs).unwrap();
    for (b, &want) in picks.iter().enumerate() {
        let row = &scores[b * m.shard_docs..(b + 1) * m.shard_docs];
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(argmax, want, "row {b}");
    }
}

#[test]
fn rank_artifact_masks_padding_and_sums_to_one() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest().clone();
    let mut q = vec![0f32; m.batch * m.embed_dim];
    let mut facts = vec![0f32; m.batch * m.max_facts * m.embed_dim];
    for (i, v) in q.iter_mut().enumerate() {
        *v = ((i * 13) as f32).sin();
    }
    for (i, v) in facts.iter_mut().enumerate() {
        *v = ((i * 17) as f32).cos() * 0.3;
    }
    let lens: Vec<i32> = vec![3, 0, 64, 10, 1, 7, 33, 2];
    let w = rt.rank(&q, &facts, &lens).unwrap();
    for (b, &l) in lens.iter().enumerate() {
        let row = &w[b * m.max_facts..(b + 1) * m.max_facts];
        let sum: f32 = row.iter().sum();
        if l == 0 {
            assert!(sum.abs() < 1e-5, "row {b} not all zero");
        } else {
            assert!((sum - 1.0).abs() < 1e-4, "row {b} sums to {sum}");
            assert!(
                row[l as usize..].iter().all(|&x| x == 0.0),
                "row {b} padding leaked"
            );
        }
    }
}

#[test]
fn shape_mismatches_rejected() {
    let Some(rt) = runtime() else { return };
    assert!(rt.embed(&[0i32; 7]).is_err());
    assert!(rt.score(&[0f32; 3], &[0f32; 3]).is_err());
    assert!(rt.rank(&[0f32; 3], &[0f32; 3], &[0i32; 1]).is_err());
}
