//! Distributed shard router: scatter-gather serving over N independent
//! TCP coordinators (PR 3 — the ROADMAP's "Distributed shards" item).
//!
//! The per-shard independence of the in-process
//! [`ShardedCuckooFilter`](crate::filter::sharded::ShardedCuckooFilter)
//! — no operation ever coordinates across shards — maps 1:1 onto
//! multi-process sharding. This subsystem is that map: a thin,
//! dependency-free L4 in front of any number of `cft-rag serve`
//! processes, routing by **entity-key ownership** with the same hash
//! family the filter shards with
//! ([`rendezvous_score`](crate::filter::fingerprint::rendezvous_score)),
//! so routing a key to a backend and sharding it inside that backend
//! never correlate.
//!
//! ```text
//!            clients (newline-delimited queries, JSON-line replies)
//!                │
//!                ▼
//!        ┌──────────────────┐   cft-rag route --backends a,b,c
//!        │      Router      │   (or embed Router in-process)
//!        │  ┌────────────┐  │
//!        │  │ Gazetteer  │  │  query → entity mentions
//!        │  └─────┬──────┘  │
//!        │  ┌─────▼──────┐  │
//!        │  │ ShardRing  │  │  mention → owning backend (rendezvous)
//!        │  └─────┬──────┘  │
//!        │  ┌─────▼──────┐  │  single owner: route whole query
//!        │  │  scatter   │  │  multi owner: fan out owned mentions,
//!        │  └─┬───┬───┬──┘  │  merge deterministically
//!        │ ┌──▼┐┌─▼─┐┌▼──┐  │
//!        │ │CP ││CP ││CP │◄─┼── ConnPool + HealthState per backend
//!        │ └─┬─┘└─┬─┘└─┬─┘  │    (prober: \x01stats every interval)
//!        └───┼────┼────┼────┘
//!            ▼    ▼    ▼
//!        ┌─────┐┌─────┐┌─────┐
//!        │coord││coord││coord│   coordinator/tcp.rs processes, each
//!        │  A  ││  B  ││  C  │   with its own sharded Cuckoo filter
//!        └─────┘└─────┘└─────┘   (in-process shards ⊂ process shards)
//! ```
//!
//! Failure model: per-request end-to-end deadlines (reactor timers
//! covering connect + write + full reply) bound the damage of a
//! slow backend to its own portion of a fan-out; transport errors and
//! coordinator refusals walk the ring's deterministic failover order
//! (minimal disruption: only the dead backend's keys move — property-
//! tested in `ring.rs`); a prober re-admits recovered backends. The
//! integration tests (`tests/router_integration.rs`) kill a live
//! backend mid-load and assert zero failed queries.
//!
//! **Replication + partitioned indexes**
//! (`RouterConfig::replication_factor`, ISSUE 4): with `R >= 1`, each
//! entity key lives
//! on its top-R ranked backends only — every backend is started with a
//! matching [`KeyPartition`](crate::rag::config::KeyPartition) and
//! indexes ~`R/N` of the keys. Reads are served by the least-loaded
//! healthy replica with ranked failover inside the replica set; the
//! `\x01insert`/`\x01delete` dynamic updates broadcast to all R
//! replicas and ack-count against `RouterConfig::write_quorum`. The
//! kill-one-backend test runs against partitioned R=2 backends and
//! stays zero-failure *and* zero-degraded. Wire format:
//! `docs/PROTOCOL.md`.
//!
//! **Elastic membership** (ISSUE 5): ring membership is no longer
//! frozen at fleet start — `\x01join <addr>`/`\x01drain <addr>` (or
//! `cft-rag route --admit/--drain`) rebalance backends in and out at
//! runtime with warm-up handoff, partition-epoch rolling, gated
//! admission, and a disowned-key drop pass. The protocol and its
//! mid-rebalance correctness argument live in [`rebalance`]; the
//! operator procedures in `docs/OPERATIONS.md`.
//!
//! **Hot-entity reply cache** (ISSUE 10): hot query replies are served
//! straight from the router when `--cache-bytes` is set, keyed on
//! (query, entity set, membership epoch) with frequency-sketch
//! admission, point-invalidated by acked writes and flushed on every
//! epoch roll — proven never-stale by `tests/prop_cache.rs` and the
//! cache modelcheck schedules. See [`cache`].

pub mod backend;
pub mod cache;
pub mod contracts;
pub mod health;
pub mod metrics;
pub mod pool;
pub mod rebalance;
pub mod ring;
pub mod scatter;

pub use backend::Backend;
pub use cache::ReplyCache;
pub use health::{EpochGate, HealthProber, HealthState};
pub use metrics::{
    BackendMetricsSnapshot, RouterMetrics, RouterMetricsSnapshot,
};
pub use pool::ConnPool;
pub use rebalance::{Membership, RebalanceReport, RingState};
pub use ring::ShardRing;
pub use scatter::Router;

use std::net::{SocketAddr, TcpListener};
use std::time::Duration;

use crate::coordinator::tcp::{parse_control, trace_reply, ControlLine};
use crate::error::Result;
use crate::obs::trace::{self, Stage};
use crate::reactor::server::{
    serve_lines, Completion, LineService, ServerConfig, ServerHandle,
    ServerStats,
};
use crate::sync::time::Instant;
use crate::sync::{mpsc, Arc, Mutex};
use crate::util::json::Json;
use crate::util::log;

/// Dispatch workers behind the front-door reactor. The reactor thread
/// never blocks, but a router dispatch does — a scattered query waits
/// for its fan-out rounds, a `\x01join` for a whole warm-up rebalance —
/// so accepted lines hop to this small fixed pool. The pool bounds
/// concurrent *dispatches*, not connections: thousands of connections
/// cost only reactor state, and the strict per-connection pipelining
/// (one dispatched line per connection at a time) keeps any one client
/// from monopolizing the workers.
const FRONT_DOOR_WORKERS: usize = 8;

/// Front-door TCP serving: the router speaks the *same* line protocol
/// as a single coordinator (`coordinator/tcp.rs`, spec in
/// `docs/PROTOCOL.md`), so clients cannot tell one node from a fleet.
/// Serving runs on the nonblocking reactor
/// ([`serve_lines`](crate::reactor::server::serve_lines)): one poll
/// thread owns every connection's read/parse/write state machine,
/// enforces `RouterConfig::max_connections` (excess connections get an
/// `overloaded` refusal) and reaps idle connections after
/// `RouterConfig::idle_timeout`.
///
/// `\x01stats` returns the router-level snapshot (per-backend
/// health/latency, the serving `ring_epoch`, the outbound
/// `deadlines_expired` counter, and the front door's own serving
/// gauges); `\x01insert`/`\x01delete` become quorum broadcasts to the
/// key's replica set; `\x01join <addr>`/`\x01drain <addr>` run an
/// elastic membership change ([`Router::join`]/[`Router::drain`] —
/// warm-up rebalancing, `router/rebalance.rs`; runbook in
/// `docs/OPERATIONS.md`). Backend-side control lines
/// (`\x01dump`/`\x01repartition`/`\x01purge`) are refused here — the
/// rebalancer drives those against backends directly. Serves until the
/// process dies — the `cft-rag route` CLI path.
pub fn serve(router: Arc<Router>, addr: &str) -> Result<()> {
    let mut handle = serve_listener(router, TcpListener::bind(addr)?)?;
    handle.inner.wait();
    Ok(())
}

/// [`serve`] against an already-bound listener, returning a handle
/// instead of blocking — the embedded/test entry point.
pub fn serve_listener(
    router: Arc<Router>,
    listener: TcpListener,
) -> Result<RouterServeHandle> {
    let local = listener.local_addr()?;
    let stats = Arc::new(ServerStats::default());
    let (work_tx, work_rx) = mpsc::channel::<WorkLine>();
    let work_rx = Arc::new(Mutex::new(work_rx));
    let workers = (0..FRONT_DOOR_WORKERS)
        .map(|i| {
            let rx = Arc::clone(&work_rx);
            let r = Arc::clone(&router);
            let serving = Arc::clone(&stats);
            std::thread::Builder::new()
                .name(format!("router-dispatch-{i}"))
                .spawn(move || {
                    // lock held only while *waiting*: recv returns the
                    // moment a line arrives, releasing the mutex before
                    // the (possibly long) dispatch runs
                    loop {
                        let next = rx.lock().unwrap().recv();
                        match next {
                            Ok(WorkLine { line, queued, enqueued, done }) => {
                                let reply = dispatch(
                                    &r, &serving, &line, queued, enqueued,
                                );
                                done.reply(reply.to_string());
                            }
                            Err(_) => break, // sender gone: shutting down
                        }
                    }
                })
                .expect("spawn router dispatch worker")
        })
        .collect();
    let config = ServerConfig {
        max_connections: router.max_connections(),
        idle_timeout: router.idle_timeout(),
        ..ServerConfig::default()
    };
    let service = Arc::new(RouterService { work: work_tx.clone() });
    let inner = serve_lines(listener, service, config, stats)?;
    log::info!("cft-rag router listening on {local} (nonblocking reactor)");
    Ok(RouterServeHandle {
        inner,
        work_tx: Some(work_tx),
        workers,
    })
}

/// A running router front door: the reactor serving thread plus its
/// dispatch worker pool. Dropping it shuts both down.
pub struct RouterServeHandle {
    inner: ServerHandle,
    work_tx: Option<mpsc::Sender<WorkLine>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

/// One accepted line in flight from the reactor to the dispatch pool:
/// the raw line, how long it sat buffered behind the connection's
/// previous request (the `reactor_queue` span), when it was handed to
/// the pool (start of the `dispatch_wait` span), and the completion
/// that queues the reply back.
struct WorkLine {
    line: String,
    queued: Duration,
    enqueued: Instant,
    done: Completion,
}

impl RouterServeHandle {
    /// The bound address (the OS-assigned port when bound to `:0`).
    pub fn addr(&self) -> SocketAddr {
        self.inner.addr()
    }

    /// The live serving-pressure counters.
    pub fn stats(&self) -> Arc<ServerStats> {
        self.inner.stats()
    }

    /// Stop accepting, drop the connections, and join the serving
    /// thread and dispatch workers. The port is released on return.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        self.inner.shutdown();
        // the RouterService sender died with the reactor; dropping ours
        // disconnects the channel and the workers drain out
        drop(self.work_tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for RouterServeHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// The front door's [`LineService`]: hands every accepted line to the
/// dispatch pool (router dispatches block on backend IO, and the
/// reactor thread must not).
struct RouterService {
    work: mpsc::Sender<WorkLine>,
}

impl LineService for RouterService {
    fn serve_line(&self, line: &str, queued: Duration, done: Completion) {
        // peel a `\x01t=` prefix only for the :quit check — the
        // dispatch worker re-strips and adopts the trace id
        if trace::strip_trace(line).1 == ":quit" {
            done.close();
            return;
        }
        // a failed send means shutdown is racing in; the moved-in
        // Completion drops with the error and answers `request dropped`
        let _ = self.work.send(WorkLine {
            line: line.to_string(),
            queued,
            enqueued: Instant::now(),
            done,
        });
    }
}

/// One front-door line to its reply — the same dispatch table as a
/// coordinator's, with fleet-level handlers.
fn dispatch(
    router: &Router,
    serving: &ServerStats,
    raw: &str,
    queued: Duration,
    enqueued: Instant,
) -> Json {
    let picked = Instant::now();
    let (wire_trace, query) = trace::strip_trace(raw);
    match parse_control(query) {
        Some(Ok(ControlLine::Stats)) => stats_reply(router, serving),
        Some(Ok(ControlLine::Trace { id })) => trace_reply(id),
        Some(Ok(ControlLine::Metrics)) => metrics_reply(router),
        Some(Ok(ControlLine::Insert { tree, node, entity })) => {
            router.update(entity, tree, node)
        }
        Some(Ok(ControlLine::Delete { entity })) => router.remove(entity),
        Some(Ok(ControlLine::Join { addr })) => router.join(addr),
        Some(Ok(ControlLine::Drain { addr })) => router.drain(addr),
        Some(Ok(
            ControlLine::Dump { .. }
            | ControlLine::Repartition { .. }
            | ControlLine::Purge
            | ControlLine::Snapshot,
        )) => Json::obj(vec![
            ("ok", Json::Bool(false)),
            (
                "error",
                Json::Str(
                    "dump/repartition/purge/snapshot are backend \
                     control lines; the rebalancer (or an operator, \
                     for snapshot) drives them on a backend — send \
                     \\x01join/\\x01drain here instead"
                        .into(),
                ),
            ),
        ]),
        Some(Err(reason)) => Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("error", Json::Str(reason)),
        ]),
        None => {
            // a query: adopt the wire trace (a traced client or an
            // upstream door sampled it) or roll the local head sampler
            let trace = if wire_trace.is_sampled() {
                wire_trace
            } else {
                router.sampler().begin()
            };
            if trace.is_sampled() {
                if !queued.is_zero() {
                    trace::record(
                        trace,
                        Stage::ReactorQueue,
                        0,
                        picked,
                        queued,
                    );
                }
                trace::record(
                    trace,
                    Stage::DispatchWait,
                    0,
                    enqueued,
                    picked.duration_since(enqueued),
                );
            }
            let mut reply = router.query_traced(query, trace);
            let total = enqueued.elapsed();
            let slow = router.sampler().is_slow(total);
            // slow queries always leave a trace: root-only when head
            // sampling skipped this request (stage spans cannot be
            // recorded retroactively)
            let trace = if slow && !trace.is_sampled() {
                trace::mint()
            } else {
                trace
            };
            trace::finish_root(
                trace,
                trace::DOOR_ROUTER,
                enqueued,
                total,
                slow,
            );
            if slow {
                trace::log_slow(trace::DOOR_ROUTER, trace, total, query);
            }
            if trace.is_sampled() {
                if let Json::Obj(m) = &mut reply {
                    m.insert("trace".into(), Json::Str(trace.to_hex()));
                }
            }
            reply
        }
    }
}

/// The router's `\x01metrics` reply: the unified registry in Prometheus
/// text exposition format, wrapped as one JSON line (mirrors the
/// coordinator door's shape, `docs/PROTOCOL.md`).
fn metrics_reply(router: &Router) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        (
            "content_type",
            Json::Str("text/plain; version=0.0.4".to_string()),
        ),
        ("text", Json::Str(router.metrics().registry().render())),
    ])
}

/// The router's `\x01stats` payload: the metrics snapshot plus the
/// front door's own serving-pressure gauges (mirroring the coordinator
/// stats shape, `docs/PROTOCOL.md`).
fn stats_reply(router: &Router, serving: &ServerStats) -> Json {
    let mut json = router.snapshot().to_json();
    if let Json::Obj(m) = &mut json {
        m.insert(
            "open_connections".into(),
            Json::Num(serving.open_connections() as f64),
        );
        m.insert(
            "reactor_queue_depth".into(),
            Json::Num(serving.reactor_queue_depth() as f64),
        );
        m.insert(
            "overloaded_rejects".into(),
            Json::Num(serving.overloaded_rejects() as f64),
        );
        m.insert(
            "idle_deadlines_expired".into(),
            Json::Num(serving.idle_deadlines_expired() as f64),
        );
        m.insert(
            "uptime_s".into(),
            Json::Num(router.uptime().as_secs_f64()),
        );
        m.insert(
            "version".into(),
            Json::Str(env!("CARGO_PKG_VERSION").to_string()),
        );
        m.insert(
            "build_profile".into(),
            Json::Str(
                if cfg!(debug_assertions) { "debug" } else { "release" }
                    .to_string(),
            ),
        );
    }
    json
}
