//! Sharded Cuckoo Filter T-RAG — the paper's system behind a
//! [`ShardedCuckooFilter`], so the serving coordinator's worker threads
//! retrieve **in parallel**: a lookup takes only the read lock of the
//! one shard that owns the key, and temperature bumps are atomic. Writer
//! holds are bounded too: a shard doubling migrates incrementally and
//! maintenance swaps re-sorted buckets in epoch-style, so no retrieval
//! ever stalls behind a full-table migration or a whole-shard re-sort.
//!
//! Semantics are identical to [`CuckooTRag`](crate::retrieval::cuckoo_rag::CuckooTRag)
//! (asserted by `rust/tests/sharded_concurrent.rs`); only the locking
//! granularity differs. See `filter::sharded` for the invariants.

use std::sync::{Arc, RwLock};

use crate::filter::cuckoo::CuckooConfig;
use crate::filter::fingerprint::entity_key;
use crate::filter::sharded::ShardedCuckooFilter;
use crate::forest::{EntityAddress, Forest};
use crate::rag::config::KeyPartition;
use crate::retrieval::{ConcurrentRetriever, Retriever};

/// The shard-parallel Cuckoo-Filter-indexed retriever.
pub struct ShardedCuckooTRag {
    /// Swapped wholesale on reindex; reads are momentary clones of the Arc.
    forest: RwLock<Arc<Forest>>,
    cf: ShardedCuckooFilter,
    /// When set, only keys whose replica set contains this backend are
    /// indexed (and dynamic updates for other keys are rejected).
    /// Behind a lock so elastic membership changes can install a new
    /// epoch's partition on a live retriever (`\x01repartition`); the
    /// lookup path never touches it.
    partition: RwLock<Option<KeyPartition>>,
}

impl ShardedCuckooTRag {
    /// Index a forest with the paper's default filter parameters.
    pub fn new(forest: Arc<Forest>, shards: usize) -> Self {
        Self::with_config(forest, CuckooConfig::default(), shards)
    }

    /// Index with custom filter parameters and shard count.
    pub fn with_config(
        forest: Arc<Forest>,
        cfg: CuckooConfig,
        shards: usize,
    ) -> Self {
        Self::with_partition(forest, cfg, shards, None)
    }

    /// Index with custom filter parameters and shard count, keeping
    /// only the keys the given [`KeyPartition`] assigns to this backend
    /// (`None` = index the whole forest). Skipped keys never touch the
    /// filter or the block arena, so a partitioned backend's index
    /// memory is roughly `R/N` of a full one — the partitioned half of
    /// the router's replication story.
    pub fn with_partition(
        forest: Arc<Forest>,
        cfg: CuckooConfig,
        shards: usize,
        partition: Option<KeyPartition>,
    ) -> Self {
        let cf = ShardedCuckooFilter::new(cfg, shards);
        let table = forest.address_table();
        for (id, addrs) in table {
            let key = entity_key(forest.entity_name(id));
            // a *warming* partition (backend joining a live fleet)
            // indexes nothing here: its keys arrive via handoff
            if partition.as_ref().map_or(true, |p| p.index_at_build(key)) {
                cf.insert(key, &addrs);
            }
        }
        ShardedCuckooTRag {
            forest: RwLock::new(forest),
            cf,
            partition: RwLock::new(partition),
        }
    }

    /// True when this retriever must index `key` (no partition = all).
    fn owns(&self, key: u64) -> bool {
        self.partition
            .read()
            .unwrap()
            .as_ref()
            .map_or(true, |p| p.owns(key))
    }

    /// The key partition currently installed, if any (a clone — the
    /// live partition can be replaced by `repartition_concurrent`).
    pub fn partition(&self) -> Option<KeyPartition> {
        self.partition.read().unwrap().clone()
    }

    /// Access the underlying sharded filter (benches/inspection).
    pub fn filter(&self) -> &ShardedCuckooFilter {
        &self.cf
    }

    /// The forest this retriever currently indexes.
    pub fn forest(&self) -> Arc<Forest> {
        self.forest.read().unwrap().clone()
    }

    /// Dynamic update: register a newly added occurrence of an entity
    /// (inserts the entity if unknown). Shard write lock only. Returns
    /// `false` when a key partition excludes the entity from this
    /// backend.
    ///
    /// push/insert take the shard lock separately, so a concurrent
    /// writer may insert the entity between our miss and our insert —
    /// the duplicate-rejected insert then loops back to `push_address`,
    /// which now succeeds. No occurrence is ever dropped.
    pub fn add_occurrence(&self, entity: &str, addr: EntityAddress) -> bool {
        let key = entity_key(entity);
        if !self.owns(key) {
            return false;
        }
        loop {
            if self.cf.push_address(key, addr) || self.cf.insert(key, &[addr]) {
                return true;
            }
        }
    }

    /// Dynamic update: remove an entity entirely (paper Algorithm 2).
    /// Un-owned keys are a no-op `false` — a partitioned backend never
    /// stored them, and probing the filter anyway could delete a
    /// fingerprint-colliding entry it *does* own.
    pub fn remove_entity(&self, entity: &str) -> bool {
        let key = entity_key(entity);
        self.owns(key) && self.cf.delete(key)
    }
}

impl ConcurrentRetriever for ShardedCuckooTRag {
    fn name(&self) -> &'static str {
        "CF T-RAG (sharded)"
    }

    fn find_concurrent(&self, entity: &str, out: &mut Vec<EntityAddress>) {
        self.cf.lookup_into(entity_key(entity), out);
    }

    /// Epoch-style: drains pending shard migrations in bounded steps and
    /// swaps re-sorted buckets in under short validated write locks —
    /// concurrent `find_concurrent` calls keep flowing throughout.
    fn maintain_concurrent(&self) {
        self.cf.maintain();
    }

    fn reindex_concurrent(&self, forest: Arc<Forest>, new_trees: &[u32]) {
        // Incremental (the paper's dynamic-update story): only the new
        // trees' addresses are inserted/appended; existing filter state —
        // including temperatures — is untouched. Shards lock per key.
        // add_occurrence skips keys a partition assigns elsewhere.
        for &t in new_trees {
            let tree = forest.tree(t);
            for idx in tree.indices() {
                let name = forest.entity_name(tree.entity(idx));
                let addr = EntityAddress::new(t, idx);
                self.add_occurrence(name, addr);
            }
        }
        *self.forest.write().unwrap() = forest;
    }

    /// Idempotent: re-sending the same occurrence (a client retrying a
    /// quorum-failed broadcast against replicas that already applied)
    /// returns `Some(false)` instead of duplicating the address. The
    /// membership check and the push take the shard lock separately, so
    /// two *concurrent* identical inserts can still both land — the
    /// guarantee is retry-idempotence, not concurrent dedup.
    fn insert_occurrence(
        &self,
        entity: &str,
        addr: EntityAddress,
    ) -> Option<bool> {
        let key = entity_key(entity);
        if !self.owns(key) {
            return Some(false);
        }
        let mut existing = Vec::new();
        self.cf.lookup_into(key, &mut existing);
        if existing.contains(&addr) {
            return Some(false); // already indexed: retried write
        }
        Some(self.add_occurrence(entity, addr))
    }

    fn remove_entity_concurrent(&self, entity: &str) -> Option<bool> {
        let key = entity_key(entity);
        if !self.owns(key) {
            return Some(false); // idempotent: never stored here
        }
        Some(self.cf.delete(key))
    }

    /// Installing a new epoch's partition changes only what dynamic
    /// updates accept; already-indexed entries keep serving until
    /// [`drop_disowned_concurrent`](ConcurrentRetriever::drop_disowned_concurrent)
    /// reclaims the ones the new partition disowns — that ordering is
    /// what lets readers see a full index throughout a membership
    /// change.
    fn repartition_concurrent(
        &self,
        partition: Option<KeyPartition>,
    ) -> bool {
        *self.partition.write().unwrap() = partition;
        true
    }

    /// Walks this retriever's own vocabulary (its forest interner) and
    /// deletes every key the current partition no longer owns.
    /// `CuckooFilter::delete` matches the exact stored key, so a
    /// never-indexed key is a no-op rather than a fingerprint-collision
    /// hazard.
    fn drop_disowned_concurrent(&self) -> Option<usize> {
        let Some(p) = self.partition() else { return Some(0) };
        let forest = self.forest();
        let mut dropped = 0usize;
        for (_, name) in forest.interner().iter() {
            let key = entity_key(name);
            if !p.owns(key) && self.cf.delete(key) {
                dropped += 1;
            }
        }
        Some(dropped)
    }

    fn index_bytes(&self) -> usize {
        self.cf.memory_bytes()
    }

    fn live_index_bytes(&self) -> usize {
        self.cf.live_memory_bytes()
    }

    fn filter_telemetry(&self) -> Option<crate::filter::FilterTelemetry> {
        Some(self.cf.telemetry())
    }

    fn probe_counters(&self) -> Option<(u64, u64)> {
        Some(self.cf.probe_counters())
    }

    fn export_index(&self) -> Option<Vec<(u64, u32, Vec<EntityAddress>)>> {
        Some(self.cf.export_entries())
    }

    fn restore_index(
        &self,
        entries: &[(u64, u32, Vec<EntityAddress>)],
    ) -> Option<usize> {
        // The snapshot is authoritative: clear the forest-built index so
        // pre-snapshot deletes stay deleted, then re-place every entry.
        // Ownership checks are skipped on purpose — the caller restores
        // the partition the snapshot was cut under.
        self.cf.clear();
        let mut restored = 0usize;
        for (key, temp, addrs) in entries {
            if self.cf.restore_entry(*key, *temp, addrs) {
                restored += 1;
            }
        }
        Some(restored)
    }
}

/// The sharded retriever also fits the classic single-threaded trait, so
/// `make_retriever` can hand it to existing pipelines and benches.
impl Retriever for ShardedCuckooTRag {
    fn name(&self) -> &'static str {
        ConcurrentRetriever::name(self)
    }

    fn find(&mut self, entity: &str) -> Vec<EntityAddress> {
        let mut out = Vec::new();
        self.find_concurrent(entity, &mut out);
        out
    }

    fn find_into(&mut self, entity: &str, out: &mut Vec<EntityAddress>) {
        self.find_concurrent(entity, out);
    }

    fn maintain(&mut self) {
        self.maintain_concurrent();
    }

    fn reindex(&mut self, forest: Arc<Forest>, new_trees: &[u32]) {
        self.reindex_concurrent(forest, new_trees);
    }

    fn index_bytes(&self) -> usize {
        ConcurrentRetriever::index_bytes(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::forest::Tree;

    fn forest() -> Arc<Forest> {
        let mut f = Forest::new();
        let a = f.intern("alpha");
        let b = f.intern("beta");
        let c = f.intern("gamma");
        let mut t0 = Tree::with_root(a);
        t0.add_child(0, b);
        t0.add_child(0, c);
        f.add_tree(t0);
        let mut t1 = Tree::with_root(b);
        t1.add_child(0, a);
        f.add_tree(t1);
        Arc::new(f)
    }

    #[test]
    fn agrees_with_scan() {
        let f = forest();
        let r = ShardedCuckooTRag::new(f.clone(), 4);
        for name in ["alpha", "beta", "gamma", "missing"] {
            let mut got = Vec::new();
            r.find_concurrent(name, &mut got);
            got.sort();
            let mut want = f
                .entity_id(name)
                .map(|id| f.scan_addresses(id))
                .unwrap_or_default();
            want.sort();
            assert_eq!(got, want, "{name}");
        }
    }

    #[test]
    fn temperatures_rise_through_shared_path() {
        let r = ShardedCuckooTRag::new(forest(), 4);
        let mut out = Vec::new();
        for _ in 0..5 {
            out.clear();
            r.find_concurrent("alpha", &mut out);
        }
        r.maintain_concurrent();
        assert_eq!(r.filter().temperature(entity_key("alpha")), Some(5));
    }

    #[test]
    fn dynamic_add_and_remove() {
        let r = ShardedCuckooTRag::new(forest(), 4);
        r.add_occurrence("delta", EntityAddress::new(5, 0));
        let mut out = Vec::new();
        r.find_concurrent("delta", &mut out);
        assert_eq!(out.len(), 1);
        r.add_occurrence("delta", EntityAddress::new(6, 3));
        out.clear();
        r.find_concurrent("delta", &mut out);
        assert_eq!(out.len(), 2);
        assert!(r.remove_entity("delta"));
        out.clear();
        r.find_concurrent("delta", &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn partition_gates_index_and_dynamic_updates() {
        use crate::rag::config::KeyPartition;

        let f = forest();
        let backends = ["a:1", "b:2", "c:3"];
        let rags: Vec<ShardedCuckooTRag> = (0..backends.len())
            .map(|i| {
                ShardedCuckooTRag::with_partition(
                    f.clone(),
                    CuckooConfig::default(),
                    2,
                    Some(KeyPartition::new(backends, i, 2).unwrap()),
                )
            })
            .collect();
        let mut out = Vec::new();
        for name in ["alpha", "beta", "gamma"] {
            let key = entity_key(name);
            let holders = rags
                .iter()
                .filter(|r| {
                    out.clear();
                    r.find_concurrent(name, &mut out);
                    !out.is_empty()
                })
                .count();
            assert_eq!(holders, 2, "{name} held by {holders} != R=2");
            for r in &rags {
                let owns = r.partition().unwrap().owns(key);
                assert_eq!(
                    r.insert_occurrence(name, EntityAddress::new(9, 0)),
                    Some(owns),
                    "{name} insert"
                );
                if owns {
                    // a retried identical insert must dedup, not append
                    assert_eq!(
                        r.insert_occurrence(name, EntityAddress::new(9, 0)),
                        Some(false),
                        "{name} retried insert"
                    );
                } else {
                    assert_eq!(
                        r.remove_entity_concurrent(name),
                        Some(false),
                        "unowned delete is an idempotent no-op"
                    );
                }
            }
        }
    }

    #[test]
    fn warming_partition_builds_empty_then_fills_by_handoff() {
        use crate::rag::config::KeyPartition;

        let f = forest();
        let r = ShardedCuckooTRag::with_partition(
            f.clone(),
            CuckooConfig::default(),
            2,
            Some(KeyPartition::joining(["a:1"], 0, 1).unwrap()),
        );
        let mut out = Vec::new();
        for name in ["alpha", "beta", "gamma"] {
            out.clear();
            r.find_concurrent(name, &mut out);
            assert!(out.is_empty(), "{name}: warming index must start empty");
        }
        // the handoff transport (`\x01insert` → insert_occurrence) fills it
        assert_eq!(
            r.insert_occurrence("alpha", EntityAddress::new(0, 0)),
            Some(true),
            "warming backends accept owned keys"
        );
        out.clear();
        r.find_concurrent("alpha", &mut out);
        assert_eq!(out.len(), 1);
    }

    #[test]
    fn repartition_then_drop_pass_reclaims_disowned_keys() {
        use crate::rag::config::KeyPartition;

        let f = forest();
        // full index: every key present, no partition
        let r = ShardedCuckooTRag::new(f.clone(), 2);
        assert_eq!(
            ConcurrentRetriever::drop_disowned_concurrent(&r),
            Some(0),
            "no partition: nothing is disowned"
        );
        let live_before = ConcurrentRetriever::live_index_bytes(&r);

        // install a 1-of-2 partition at a later epoch; serving is
        // unchanged until the drop pass runs
        let p = KeyPartition::new(["a:1", "b:2"], 0, 1)
            .unwrap()
            .with_epoch(1);
        let owned: Vec<&str> = ["alpha", "beta", "gamma"]
            .into_iter()
            .filter(|n| p.owns(entity_key(n)))
            .collect();
        assert!(ConcurrentRetriever::repartition_concurrent(
            &r,
            Some(p.clone())
        ));
        assert_eq!(r.partition().unwrap().epoch(), 1);
        let mut out = Vec::new();
        for name in ["alpha", "beta", "gamma"] {
            out.clear();
            r.find_concurrent(name, &mut out);
            assert!(!out.is_empty(), "{name} still serving pre-drop");
        }

        // the drop pass reclaims exactly the disowned keys
        let dropped =
            ConcurrentRetriever::drop_disowned_concurrent(&r).unwrap();
        assert_eq!(dropped, 3 - owned.len(), "owned: {owned:?}");
        for name in ["alpha", "beta", "gamma"] {
            out.clear();
            r.find_concurrent(name, &mut out);
            assert_eq!(
                !out.is_empty(),
                owned.contains(&name),
                "{name} post-drop"
            );
        }
        if dropped > 0 {
            assert!(
                ConcurrentRetriever::live_index_bytes(&r) < live_before,
                "drop pass must shrink live index bytes"
            );
        }
        // idempotent: a second pass finds nothing left to drop
        assert_eq!(
            ConcurrentRetriever::drop_disowned_concurrent(&r),
            Some(0)
        );
    }

    #[test]
    fn retriever_trait_delegates() {
        let mut r = ShardedCuckooTRag::new(forest(), 2);
        assert_eq!(Retriever::name(&r), "CF T-RAG (sharded)");
        assert_eq!(r.find("alpha").len(), 2);
        assert!(Retriever::index_bytes(&r) > 0);
    }

    #[test]
    fn exposes_filter_telemetry_and_probe_counters() {
        let r = ShardedCuckooTRag::new(forest(), 4);
        let mut out = Vec::new();
        for _ in 0..3 {
            out.clear();
            r.find_concurrent("alpha", &mut out);
        }
        let t = ConcurrentRetriever::filter_telemetry(&r).unwrap();
        assert_eq!(t.shards, 4);
        assert!(t.entries >= 3, "alpha/beta/gamma indexed");
        assert_eq!(t.lookups, 3);
        let (lookups, probed) = ConcurrentRetriever::probe_counters(&r).unwrap();
        assert_eq!(lookups, 3);
        assert!(probed >= 3);
        // baselines stay telemetry-free through the default methods
        let mutex = crate::retrieval::MutexRetriever::new(Box::new(
            crate::retrieval::naive::NaiveTRag::new(forest()),
        ));
        assert!(ConcurrentRetriever::filter_telemetry(&mutex).is_none());
        assert!(ConcurrentRetriever::probe_counters(&mutex).is_none());
    }

    #[test]
    fn restore_index_is_authoritative_over_forest_build() {
        let f = forest();
        let r = ShardedCuckooTRag::new(f.clone(), 4);
        // dynamic churn the forest knows nothing about
        r.add_occurrence("delta", EntityAddress::new(5, 0));
        assert!(r.remove_entity("beta"));
        let exported = ConcurrentRetriever::export_index(&r).unwrap();

        // a fresh boot rebuilds beta from the forest...
        let warm = ShardedCuckooTRag::new(f, 4);
        let mut out = Vec::new();
        warm.find_concurrent("beta", &mut out);
        assert!(!out.is_empty(), "forest build resurrects beta");
        // ...until the snapshot restore makes the recorded state win
        let n = ConcurrentRetriever::restore_index(&warm, &exported).unwrap();
        assert_eq!(n, exported.len());
        out.clear();
        warm.find_concurrent("beta", &mut out);
        assert!(out.is_empty(), "acked delete must stay deleted");
        out.clear();
        warm.find_concurrent("delta", &mut out);
        assert_eq!(out.len(), 1, "acked insert must survive");

        // baselines opt out through the defaults
        let mutex = crate::retrieval::MutexRetriever::new(Box::new(
            crate::retrieval::naive::NaiveTRag::new(forest()),
        ));
        assert!(ConcurrentRetriever::export_index(&mutex).is_none());
        assert!(ConcurrentRetriever::restore_index(&mutex, &[]).is_none());
    }
}
