//! Minimal TCP line protocol in front of the coordinator: one query per
//! line in, one JSON object per line out. `cft-rag serve --port N`.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use crate::coordinator::server::Coordinator;
use crate::error::Result;
use crate::util::json::Json;

/// Serve until the process is killed. Each connection gets a thread;
/// queries are newline-delimited; responses are JSON lines.
pub fn serve(coordinator: Arc<Coordinator>, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    log::info!("cft-rag listening on {addr}");
    for stream in listener.incoming() {
        accept_one(&coordinator, stream);
    }
    Ok(())
}

/// Handle one `accept()` outcome. Accept failures are *transient* from
/// the listener's point of view — a reset half-open connection
/// (`ECONNABORTED`), fd exhaustion (`EMFILE`), an interrupted syscall —
/// so they are logged and survived; the pre-PR-2 `stream?` turned any
/// one of them into the death of the whole listener.
fn accept_one(coordinator: &Arc<Coordinator>, stream: std::io::Result<TcpStream>) {
    match stream {
        Ok(stream) => {
            let c = coordinator.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(c, stream);
            });
        }
        Err(e) => {
            log::warn!("accept failed (transient; listener continues): {e}");
            // A *persistent* failure (e.g. EMFILE under fd exhaustion)
            // would otherwise hot-spin the accept loop at 100% CPU and
            // flood the log; a short pause bounds the retry rate while
            // still recovering as soon as the condition clears. EINTR
            // is the one kind where an immediate retry is always right.
            if e.kind() != std::io::ErrorKind::Interrupted {
                std::thread::sleep(std::time::Duration::from_millis(50));
            }
        }
    }
}

fn handle_conn(coordinator: Arc<Coordinator>, stream: TcpStream) -> std::io::Result<()> {
    let peer = stream.peer_addr()?;
    log::debug!("connection from {peer}");
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    for line in reader.lines() {
        let line = line?;
        let query = line.trim();
        if query.is_empty() {
            continue;
        }
        if query == ":quit" {
            break;
        }
        let reply = respond(&coordinator, query);
        writer.write_all(reply.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
    }
    Ok(())
}

/// Build the JSON reply for one query (exposed for tests).
pub fn respond(coordinator: &Coordinator, query: &str) -> Json {
    match coordinator.query_blocking(query) {
        Ok(r) => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("answer", Json::Str(r.answer)),
            (
                "entities",
                Json::Arr(r.entities.into_iter().map(Json::Str).collect()),
            ),
            ("facts", Json::Num(r.fact_count as f64)),
            (
                "retrieval_us",
                Json::Num(r.retrieval_time.as_micros() as f64),
            ),
            ("total_ms", Json::Num(r.total_time.as_millis() as f64)),
        ]),
        Err(e) => Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("error", Json::Str(e.to_string())),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::CoordinatorConfig;
    use crate::data::corpus::corpus_from_texts;
    use crate::data::hospital::{HospitalConfig, HospitalDataset};
    use crate::rag::config::RagConfig;
    use crate::runtime::engine::{Engine, NativeEngine};
    use std::io::{BufRead, BufReader, Write};

    fn coordinator() -> Arc<Coordinator> {
        let ds = HospitalDataset::generate(HospitalConfig {
            trees: 4,
            ..HospitalConfig::default()
        });
        let forest = Arc::new(ds.build_forest());
        let docs = corpus_from_texts(&ds.documents());
        let engine: Arc<dyn Engine> = Arc::new(NativeEngine::new());
        Arc::new(
            Coordinator::start(
                forest,
                docs,
                engine,
                RagConfig::default(),
                CoordinatorConfig { workers: 2, ..Default::default() },
            )
            .unwrap(),
        )
    }

    #[test]
    fn respond_builds_json() {
        let c = coordinator();
        let json = respond(&c, "describe the hierarchy around cardiology");
        assert_eq!(json.get("ok"), Some(&Json::Bool(true)));
        assert!(json.get("answer").unwrap().as_str().unwrap().len() > 10);
    }

    #[test]
    fn accept_error_does_not_kill_listener() {
        let c = coordinator();
        // a transient accept failure is absorbed (pre-PR-2 this bubbled
        // out of serve() and killed the listener)...
        for kind in [
            std::io::ErrorKind::ConnectionAborted,
            std::io::ErrorKind::Interrupted,
            std::io::ErrorKind::Other, // e.g. EMFILE surfaces as Other/Uncategorized
        ] {
            accept_one(&c, Err(std::io::Error::from(kind)));
        }
        // ...and the very same accept path still serves a real
        // connection afterwards.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut client = TcpStream::connect(addr).unwrap();
            client
                .write_all(b"what is the parent unit of cardiology\n:quit\n")
                .unwrap();
            let mut reader = BufReader::new(client);
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            line
        });
        let (stream, _) = listener.accept().unwrap();
        accept_one(&c, Ok(stream));
        let line = client.join().unwrap();
        assert!(line.contains("\"ok\":true"), "{line}");
    }

    #[test]
    fn tcp_roundtrip() {
        let c = coordinator();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = {
            let c = c.clone();
            std::thread::spawn(move || {
                let (stream, _) = listener.accept().unwrap();
                handle_conn(c, stream).unwrap();
            })
        };
        let mut client = TcpStream::connect(addr).unwrap();
        client
            .write_all(b"what is the parent unit of cardiology\n:quit\n")
            .unwrap();
        let mut reader = BufReader::new(client.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":true"), "{line}");
        server.join().unwrap();
    }
}
