//! The multiplexed outbound request driver: every router-side wire
//! exchange — query fan-outs, health probes, rebalance dump/replay
//! streams — is one nonblocking state machine on a single shared
//! reactor thread, instead of a blocked OS thread per in-flight
//! request.
//!
//! Callers stay synchronous: [`NetDriver::exchange`] submits one
//! round trip and blocks the *calling* thread on a channel until the
//! reply lands; [`NetDriver::exchange_many`] submits a whole fan-out
//! at once, so N sub-requests overlap on the wire while costing zero
//! additional threads. The blocking moves from "one thread per
//! socket" to "one thread per caller", and callers (the query path,
//! the prober, a rebalance) were already threads.
//!
//! # Deadlines
//!
//! Each [`Exchange`] carries an **absolute end-to-end deadline**
//! covering connect + write + the full reply — not per-stream socket
//! timeouts set once at connect. A backend that dribbles one byte per
//! `read_timeout` can stretch a socket-timeout budget arbitrarily;
//! against the driver's deadline it cannot exceed the configured
//! budget by a single tick. An expired deadline fails the exchange
//! with `TimedOut`, bumps the driver's `deadlines_expired` counter
//! (surfaced in router `\x01stats`), and drops the socket rather than
//! pooling a stream with an unread reply in flight.
//!
//! # Pooled-connection retry
//!
//! The pool makes no liveness promise for idle sockets, so a failure
//! on a pooled connection clears the pool (its siblings are from the
//! same era and equally suspect) and retries **once** on a fresh
//! connection within the same deadline — the same policy the blocking
//! `router/backend.rs` path had. Failures on the fresh connection are
//! authoritative and surface to the caller.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::os::fd::AsRawFd;
use std::time::{Duration, Instant};

use crate::reactor::sys::{Event, Interest, Poller, Waker};
use crate::reactor::timer::Timers;
use crate::router::pool::ConnPool;
use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::sync::{mpsc, Arc, Mutex};

/// Token of the wakeup socket; ops get tokens from 2 up.
const TOKEN_WAKER: u64 = 1;
const FIRST_OP: u64 = 2;

/// Largest accepted reply line (dump streams are the big ones).
const MAX_REPLY_BYTES: usize = 64 * 1024 * 1024;

/// Grace past the latest submitted deadline before a caller declares
/// the driver itself wedged.
const DRIVER_SLACK: Duration = Duration::from_secs(5);

/// One outbound round trip: write `line`, read one reply line.
#[derive(Debug)]
pub struct Exchange {
    /// Idle-socket pool for the target backend (also names the addr).
    pub pool: Arc<ConnPool>,
    /// Request line, without the trailing newline.
    pub line: String,
    /// Budget for each fresh TCP connect attempt (still bounded by
    /// `deadline`). Zero means "whatever the deadline allows".
    pub connect_timeout: Duration,
    /// Absolute end-to-end deadline: connect + write + full reply.
    pub deadline: Instant,
}

type ReplyTx = mpsc::Sender<(usize, io::Result<String>, Duration)>;

/// Where an op currently is in its round trip.
#[derive(Debug, PartialEq, Eq)]
enum Phase {
    /// Waiting for a nonblocking connect to finish.
    Connecting,
    /// Writing the request line.
    Writing,
    /// Accumulating the reply until `\n`.
    Reading,
}

#[derive(Debug)]
struct Op {
    pool: Arc<ConnPool>,
    /// Request bytes including the trailing newline.
    wire: Vec<u8>,
    /// Pre-resolved candidate addresses (resolved on the caller
    /// thread so DNS never blocks the loop).
    addrs: Vec<SocketAddr>,
    addr_idx: usize,
    connect_timeout: Duration,
    /// Deadline of the current connect attempt (≤ `deadline`).
    connect_deadline: Instant,
    deadline: Instant,
    started: Instant,
    phase: Phase,
    stream: Option<TcpStream>,
    written: usize,
    rbuf: Vec<u8>,
    /// The current socket came from the pool.
    from_pool: bool,
    /// The one pooled-failure retry was already spent.
    retried: bool,
    tx: ReplyTx,
    slot: usize,
}

/// A submitted-but-not-yet-admitted exchange.
#[derive(Debug)]
struct Pending {
    pool: Arc<ConnPool>,
    wire: Vec<u8>,
    addrs: Vec<SocketAddr>,
    connect_timeout: Duration,
    deadline: Instant,
    started: Instant,
    tx: ReplyTx,
    slot: usize,
}

#[derive(Debug, Default)]
struct DriverCounters {
    deadlines_expired: AtomicU64,
    inflight: AtomicU64,
}

#[derive(Debug)]
struct Shared {
    submitted: Mutex<Vec<Pending>>,
    waker: Waker,
    stop: AtomicBool,
    counters: DriverCounters,
}

/// Handle to the shared outbound reactor. Cheap to share via `Arc`;
/// dropping the last handle stops and joins the loop thread.
#[derive(Debug)]
pub struct NetDriver {
    shared: Arc<Shared>,
    thread: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl NetDriver {
    /// Start the driver loop on its own named thread.
    pub fn start() -> io::Result<NetDriver> {
        let shared = Arc::new(Shared {
            submitted: Mutex::new(Vec::new()),
            waker: Waker::new()?,
            stop: AtomicBool::new(false),
            counters: DriverCounters::default(),
        });
        let poller = Poller::new()?;
        poller.register(shared.waker.raw_fd(), TOKEN_WAKER, Interest::READ)?;
        let mut driver_loop = DriverLoop {
            poller,
            shared: Arc::clone(&shared),
            timers: Timers::new(),
            ops: HashMap::new(),
            next_token: FIRST_OP,
        };
        let thread = std::thread::Builder::new()
            .name("net-driver".to_string())
            .spawn(move || driver_loop.run())?;
        Ok(NetDriver { shared, thread: Mutex::new(Some(thread)) })
    }

    /// Exchanges that have failed by deadline expiry (counter) — the
    /// router reports this as `deadlines_expired` in `\x01stats`.
    pub fn deadlines_expired(&self) -> u64 {
        self.shared.counters.deadlines_expired.load(Ordering::Relaxed)
    }

    /// Round trips currently on the wire (gauge).
    pub fn inflight(&self) -> u64 {
        self.shared.counters.inflight.load(Ordering::Relaxed)
    }

    /// One blocking round trip (the fan-out-of-one case).
    pub fn exchange(&self, spec: Exchange) -> io::Result<String> {
        self.exchange_many(vec![spec])
            .pop()
            .expect("one spec yields one result")
            .0
    }

    /// Submit every exchange at once and block the calling thread
    /// until all replies (or failures) are in. Result `i` belongs to
    /// spec `i`; the `Duration` is that exchange's wire time.
    pub fn exchange_many(
        &self,
        specs: Vec<Exchange>,
    ) -> Vec<(io::Result<String>, Duration)> {
        let n = specs.len();
        let mut results: Vec<Option<(io::Result<String>, Duration)>> =
            (0..n).map(|_| None).collect();
        if n == 0 {
            return Vec::new();
        }
        let (tx, rx) = mpsc::channel();
        let started = Instant::now();
        let mut latest_deadline = started;
        let mut submitted = 0usize;
        for (slot, spec) in specs.into_iter().enumerate() {
            debug_assert!(
                !spec.line.contains('\n'),
                "protocol is one line per request"
            );
            latest_deadline = latest_deadline.max(spec.deadline);
            // resolve on the caller thread: DNS must not stall the loop
            let addrs: Vec<SocketAddr> =
                match spec.pool.addr().to_socket_addrs() {
                    Ok(it) => it.collect(),
                    Err(e) => {
                        results[slot] = Some((Err(e), started.elapsed()));
                        continue;
                    }
                };
            if addrs.is_empty() {
                results[slot] = Some((
                    Err(io::Error::new(
                        io::ErrorKind::AddrNotAvailable,
                        format!(
                            "no addresses resolved for {}",
                            spec.pool.addr()
                        ),
                    )),
                    started.elapsed(),
                ));
                continue;
            }
            let mut wire = spec.line.into_bytes();
            wire.push(b'\n');
            self.shared.submitted.lock().unwrap().push(Pending {
                pool: spec.pool,
                wire,
                addrs,
                connect_timeout: spec.connect_timeout,
                deadline: spec.deadline,
                started: Instant::now(),
                tx: tx.clone(),
                slot,
            });
            submitted += 1;
        }
        drop(tx);
        if submitted > 0 {
            self.shared.waker.wake();
        }
        let hard_stop = latest_deadline + DRIVER_SLACK;
        let mut received = 0usize;
        while received < submitted {
            let budget = hard_stop.saturating_duration_since(Instant::now());
            match rx.recv_timeout(budget.max(Duration::from_millis(1))) {
                Ok((slot, result, elapsed)) => {
                    results[slot] = Some((result, elapsed));
                    received += 1;
                }
                Err(_) => break, // driver wedged or gone: fill below
            }
        }
        results
            .into_iter()
            .map(|r| {
                r.unwrap_or_else(|| {
                    (
                        Err(io::Error::new(
                            io::ErrorKind::TimedOut,
                            "net driver unresponsive",
                        )),
                        started.elapsed(),
                    )
                })
            })
            .collect()
    }
}

impl Drop for NetDriver {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.waker.wake();
        if let Some(t) = self.thread.lock().unwrap().take() {
            let _ = t.join();
        }
    }
}

struct DriverLoop {
    poller: Poller,
    shared: Arc<Shared>,
    timers: Timers,
    ops: HashMap<u64, Op>,
    next_token: u64,
}

impl DriverLoop {
    fn run(&mut self) {
        let mut events: Vec<Event> = Vec::new();
        loop {
            let timeout = self
                .timers
                .next_deadline()
                .map(|d| d.saturating_duration_since(Instant::now()));
            match self.poller.wait(&mut events, timeout) {
                Ok(_) => {}
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
            if self.shared.stop.load(Ordering::SeqCst) {
                break;
            }
            for &ev in events.iter() {
                if ev.token == TOKEN_WAKER {
                    self.shared.waker.drain();
                } else {
                    self.op_ready(ev);
                }
            }
            self.admit_submitted();
            self.fire_timers();
        }
        // refuse whatever is still queued or in flight so callers
        // unblock immediately instead of waiting out the slack
        for p in self.shared.submitted.lock().unwrap().drain(..) {
            let _ = p.tx.send((
                p.slot,
                Err(io::Error::other("net driver stopped")),
                p.started.elapsed(),
            ));
        }
        for (_, op) in std::mem::take(&mut self.ops) {
            let _ = op.tx.send((
                op.slot,
                Err(io::Error::other("net driver stopped")),
                op.started.elapsed(),
            ));
        }
        self.shared.counters.inflight.store(0, Ordering::Relaxed);
    }

    fn admit_submitted(&mut self) {
        let pending = std::mem::take(
            &mut *self.shared.submitted.lock().unwrap(),
        );
        for p in pending {
            let token = self.next_token;
            self.next_token += 1;
            let now = Instant::now();
            let mut op = Op {
                pool: p.pool,
                wire: p.wire,
                addrs: p.addrs,
                addr_idx: 0,
                connect_timeout: p.connect_timeout,
                connect_deadline: p.deadline,
                deadline: p.deadline,
                started: p.started,
                phase: Phase::Connecting,
                stream: None,
                written: 0,
                rbuf: Vec::new(),
                from_pool: false,
                retried: false,
                tx: p.tx,
                slot: p.slot,
            };
            self.shared.counters.inflight.fetch_add(1, Ordering::Relaxed);
            self.timers.arm(op.deadline, token);
            if now >= op.deadline {
                self.expire(token, op);
                continue;
            }
            if let Some(stream) = op.pool.take_idle() {
                // pooled sockets are already nonblocking (they were
                // pooled by this loop); re-assert for the transition
                // period where blocking call sites pooled them
                let _ = stream.set_nonblocking(true);
                op.stream = Some(stream);
                op.from_pool = true;
                op.phase = Phase::Writing;
                self.ops.insert(token, op);
                self.advance(token);
            } else {
                self.start_connect_attempt(token, op);
            }
        }
    }

    /// Begin (or continue, on `addr_idx`) a fresh connect for `op`,
    /// inserting it into the op table. Consumes the op by value so
    /// retry paths can rebuild state cleanly.
    fn start_connect_attempt(&mut self, token: u64, mut op: Op) {
        loop {
            if op.addr_idx >= op.addrs.len() {
                self.fail_or_retry(
                    token,
                    op,
                    io::Error::new(
                        io::ErrorKind::ConnectionRefused,
                        "all resolved addresses failed to connect",
                    ),
                );
                return;
            }
            let addr = op.addrs[op.addr_idx];
            let now = Instant::now();
            op.connect_deadline = if op.connect_timeout.is_zero() {
                op.deadline
            } else {
                op.deadline.min(now + op.connect_timeout)
            };
            match connect_nonblocking(&addr, op.connect_deadline) {
                Ok((stream, connected)) => {
                    let _ = stream.set_nodelay(true);
                    op.stream = Some(stream);
                    op.from_pool = false;
                    if connected {
                        op.phase = Phase::Writing;
                        self.ops.insert(token, op);
                        self.advance(token);
                    } else {
                        op.phase = Phase::Connecting;
                        let fd = op
                            .stream
                            .as_ref()
                            .expect("just set")
                            .as_raw_fd();
                        self.timers.arm(op.connect_deadline, token);
                        if self
                            .poller
                            .register(fd, token, Interest::WRITE)
                            .is_err()
                        {
                            op.addr_idx += 1;
                            op.stream = None;
                            continue;
                        }
                        self.ops.insert(token, op);
                    }
                    return;
                }
                Err(_) => {
                    op.addr_idx += 1;
                    continue;
                }
            }
        }
    }

    fn op_ready(&mut self, ev: Event) {
        let token = ev.token;
        let phase_is_connecting = match self.ops.get(&token) {
            Some(op) => op.phase == Phase::Connecting,
            None => return, // stale event (lazy timer/close races)
        };
        if phase_is_connecting {
            if ev.writable || ev.broken {
                self.finish_connect(token);
            }
            return;
        }
        self.advance(token);
    }

    /// A connecting socket reported writable: read back SO_ERROR and
    /// either proceed to Writing or move to the next address.
    fn finish_connect(&mut self, token: u64) {
        let op = match self.ops.get_mut(&token) {
            Some(op) => op,
            None => return,
        };
        let stream = op.stream.as_ref().expect("connecting ops have streams");
        match connect_outcome(stream) {
            Ok(()) => {
                let _ = stream.set_nodelay(true);
                op.phase = Phase::Writing;
                self.advance(token);
            }
            Err(_) => {
                let mut op = self.ops.remove(&token).expect("present");
                if let Some(s) = op.stream.take() {
                    let _ = self.poller.deregister(s.as_raw_fd());
                }
                op.addr_idx += 1;
                self.start_connect_attempt(token, op);
            }
        }
    }

    /// Drive Writing/Reading IO until `WouldBlock`, completion, or
    /// failure, then reconcile poller registration.
    fn advance(&mut self, token: u64) {
        let op = match self.ops.get_mut(&token) {
            Some(op) => op,
            None => return,
        };
        let mut tmp = [0u8; 8192];
        let failure: Option<io::Error> = loop {
            let stream = op.stream.as_mut().expect("active ops have streams");
            match op.phase {
                Phase::Connecting => unreachable!("handled in op_ready"),
                Phase::Writing => {
                    if op.written >= op.wire.len() {
                        op.phase = Phase::Reading;
                        continue;
                    }
                    match stream.write(&op.wire[op.written..]) {
                        Ok(0) => {
                            break Some(io::Error::new(
                                io::ErrorKind::WriteZero,
                                format!(
                                    "{} stopped accepting the request",
                                    op.pool.addr()
                                ),
                            ))
                        }
                        Ok(n) => op.written += n,
                        Err(e)
                            if e.kind() == io::ErrorKind::WouldBlock =>
                        {
                            break None
                        }
                        Err(e)
                            if e.kind() == io::ErrorKind::Interrupted =>
                        {
                            continue
                        }
                        Err(e) => break Some(e),
                    }
                }
                Phase::Reading => {
                    if op.rbuf.contains(&b'\n') {
                        self.complete(token);
                        return;
                    }
                    match stream.read(&mut tmp) {
                        Ok(0) => {
                            break Some(io::Error::new(
                                io::ErrorKind::UnexpectedEof,
                                format!(
                                    "{} closed before replying",
                                    op.pool.addr()
                                ),
                            ))
                        }
                        Ok(n) => {
                            op.rbuf.extend_from_slice(&tmp[..n]);
                            if op.rbuf.len() > MAX_REPLY_BYTES {
                                break Some(io::Error::new(
                                    io::ErrorKind::InvalidData,
                                    format!(
                                        "reply from {} exceeds {} bytes",
                                        op.pool.addr(),
                                        MAX_REPLY_BYTES
                                    ),
                                ));
                            }
                        }
                        Err(e)
                            if e.kind() == io::ErrorKind::WouldBlock =>
                        {
                            break None
                        }
                        Err(e)
                            if e.kind() == io::ErrorKind::Interrupted =>
                        {
                            continue
                        }
                        Err(e) => break Some(e),
                    }
                }
            }
        };
        match failure {
            Some(e) => {
                let mut op = self.ops.remove(&token).expect("present");
                if let Some(s) = op.stream.take() {
                    let _ = self.poller.deregister(s.as_raw_fd());
                }
                self.fail_or_retry(token, op, e);
            }
            None => {
                // WouldBlock: (re-)register for what the phase needs
                let op = self.ops.get(&token).expect("present");
                let want = match op.phase {
                    Phase::Writing => Interest::WRITE,
                    _ => Interest::READ,
                };
                let fd = op
                    .stream
                    .as_ref()
                    .expect("active ops have streams")
                    .as_raw_fd();
                // reregister first (the common case once registered);
                // fall back to register for the first transition off a
                // pooled or freshly-connected socket
                if self.poller.reregister(fd, token, want).is_err()
                    && self.poller.register(fd, token, want).is_err()
                {
                    let mut op = self.ops.remove(&token).expect("present");
                    op.stream = None;
                    self.fail_or_retry(
                        token,
                        op,
                        io::Error::other("poller registration failed"),
                    );
                }
            }
        }
    }

    /// The reply line is complete: deliver it and maybe pool the
    /// socket back.
    fn complete(&mut self, token: u64) {
        let mut op = match self.ops.remove(&token) {
            Some(op) => op,
            None => return,
        };
        let stream = op.stream.take().expect("completing ops have streams");
        let _ = self.poller.deregister(stream.as_raw_fd());
        let nl = op
            .rbuf
            .iter()
            .position(|&b| b == b'\n')
            .expect("complete() requires a newline");
        // pool the socket back only when the reply ended *exactly* at
        // the newline — any trailing bytes mean framing drift and the
        // socket cannot be trusted for the next request
        if nl == op.rbuf.len() - 1 {
            op.pool.put_back(stream);
        }
        let reply =
            String::from_utf8_lossy(&op.rbuf[..nl]).trim().to_string();
        self.shared.counters.inflight.fetch_sub(1, Ordering::Relaxed);
        let _ = op.tx.send((op.slot, Ok(reply), op.started.elapsed()));
    }

    /// A pooled-socket failure retries once on a fresh connection
    /// (clearing the pool); anything else is delivered to the caller.
    fn fail_or_retry(&mut self, token: u64, mut op: Op, e: io::Error) {
        if op.from_pool && !op.retried {
            op.pool.clear();
            op.retried = true;
            op.from_pool = false;
            op.addr_idx = 0;
            op.written = 0;
            op.rbuf.clear();
            op.stream = None;
            op.phase = Phase::Connecting;
            if Instant::now() < op.deadline {
                self.start_connect_attempt(token, op);
                return;
            }
            self.expire(token, op);
            return;
        }
        self.shared.counters.inflight.fetch_sub(1, Ordering::Relaxed);
        let _ = op.tx.send((op.slot, Err(e), op.started.elapsed()));
    }

    /// Deliver a deadline expiry (op already removed from the table).
    fn expire(&mut self, _token: u64, op: Op) {
        if let Some(s) = op.stream.as_ref() {
            let _ = self.poller.deregister(s.as_raw_fd());
        }
        self.shared
            .counters
            .deadlines_expired
            .fetch_add(1, Ordering::Relaxed);
        self.shared.counters.inflight.fetch_sub(1, Ordering::Relaxed);
        let _ = op.tx.send((
            op.slot,
            Err(io::Error::new(
                io::ErrorKind::TimedOut,
                format!(
                    "request to {} exceeded its deadline",
                    op.pool.addr()
                ),
            )),
            op.started.elapsed(),
        ));
    }

    fn fire_timers(&mut self) {
        let now = Instant::now();
        let mut fired = Vec::new();
        if self.timers.pop_expired(now, &mut fired) == 0 {
            return;
        }
        for token in fired {
            let (expired, connect_expired) = match self.ops.get(&token) {
                None => continue, // completed: stale deadline
                Some(op) => (
                    now >= op.deadline,
                    op.phase == Phase::Connecting
                        && now >= op.connect_deadline,
                ),
            };
            if expired {
                let op = self.ops.remove(&token).expect("present");
                self.expire(token, op);
            } else if connect_expired {
                // this connect attempt timed out; move to the next
                // candidate address (or the retry/fail path)
                let mut op = self.ops.remove(&token).expect("present");
                if let Some(s) = op.stream.take() {
                    let _ = self.poller.deregister(s.as_raw_fd());
                }
                op.addr_idx += 1;
                self.start_connect_attempt(token, op);
            }
            // else: stale hint (deadline pushed by retry); the real
            // deadline timer is still armed
        }
    }
}

/// Begin a TCP connect that never blocks the loop. On Linux this is a
/// raw `SOCK_NONBLOCK` connect completed via writability +
/// `SO_ERROR`; elsewhere it degrades to a bounded blocking
/// `connect_timeout` on the driver thread (a documented portability
/// compromise — production and CI are Linux).
#[cfg(target_os = "linux")]
fn connect_nonblocking(
    addr: &SocketAddr,
    _deadline: Instant,
) -> io::Result<(TcpStream, bool)> {
    crate::reactor::sys::start_connect(addr)
}

#[cfg(not(target_os = "linux"))]
fn connect_nonblocking(
    addr: &SocketAddr,
    deadline: Instant,
) -> io::Result<(TcpStream, bool)> {
    let budget = deadline
        .saturating_duration_since(Instant::now())
        .max(Duration::from_millis(1));
    let stream = TcpStream::connect_timeout(addr, budget)?;
    stream.set_nonblocking(true)?;
    Ok((stream, true))
}

/// Outcome of a pending nonblocking connect (Linux: `SO_ERROR`).
#[cfg(target_os = "linux")]
fn connect_outcome(stream: &TcpStream) -> io::Result<()> {
    crate::reactor::sys::connect_result(stream)
}

#[cfg(not(target_os = "linux"))]
fn connect_outcome(_stream: &TcpStream) -> io::Result<()> {
    Ok(()) // connects complete synchronously on the fallback path
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader};
    use std::net::TcpListener;

    /// A line-echo server that answers `[line]` per request line, with
    /// an optional fixed delay before each reply.
    fn echo_server(delay: Duration, conns: usize) -> String {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            for _ in 0..conns {
                let Ok((stream, _)) = listener.accept() else { return };
                std::thread::spawn(move || {
                    let mut reader =
                        BufReader::new(stream.try_clone().unwrap());
                    let mut writer = stream;
                    let mut line = String::new();
                    while reader.read_line(&mut line).unwrap_or(0) > 0 {
                        if !delay.is_zero() {
                            std::thread::sleep(delay);
                        }
                        let reply = format!("[{}]\n", line.trim());
                        if writer.write_all(reply.as_bytes()).is_err() {
                            return;
                        }
                        line.clear();
                    }
                });
            }
        });
        addr
    }

    fn spec(pool: &Arc<ConnPool>, line: &str, budget: Duration) -> Exchange {
        Exchange {
            pool: Arc::clone(pool),
            line: line.to_string(),
            connect_timeout: Duration::from_secs(2),
            deadline: Instant::now() + budget,
        }
    }

    #[test]
    fn exchange_roundtrips_and_pools_the_socket() {
        let addr = echo_server(Duration::ZERO, 1);
        let driver = NetDriver::start().unwrap();
        let pool = Arc::new(ConnPool::new(addr, 2));
        let reply = driver
            .exchange(spec(&pool, "hello", Duration::from_secs(10)))
            .unwrap();
        assert_eq!(reply, "[hello]");
        assert_eq!(pool.idle_count(), 1, "clean roundtrip pools the socket");
        // second exchange reuses it: the server accepts only one conn
        let reply = driver
            .exchange(spec(&pool, "again", Duration::from_secs(10)))
            .unwrap();
        assert_eq!(reply, "[again]");
    }

    #[test]
    fn fan_out_overlaps_on_one_thread() {
        // three servers that each take ~80ms to answer: a serial
        // client needs ~240ms, the multiplexed fan-out ~80ms
        let pools: Vec<Arc<ConnPool>> = (0..3)
            .map(|_| {
                Arc::new(ConnPool::new(
                    echo_server(Duration::from_millis(80), 1),
                    2,
                ))
            })
            .collect();
        let driver = NetDriver::start().unwrap();
        let specs = pools
            .iter()
            .enumerate()
            .map(|(i, p)| spec(p, &format!("q{i}"), Duration::from_secs(10)))
            .collect();
        let t = Instant::now();
        let results = driver.exchange_many(specs);
        let elapsed = t.elapsed();
        for (i, (r, _)) in results.iter().enumerate() {
            assert_eq!(r.as_ref().unwrap(), &format!("[q{i}]"));
        }
        assert!(
            elapsed < Duration::from_millis(200),
            "fan-out must overlap, took {elapsed:?}"
        );
    }

    #[test]
    fn deadline_bounds_a_dribbling_backend_end_to_end() {
        // server answers after 5s; a 150ms end-to-end deadline must
        // fail fast with TimedOut and bump the counter
        let addr = echo_server(Duration::from_secs(5), 1);
        let driver = NetDriver::start().unwrap();
        let pool = Arc::new(ConnPool::new(addr, 2));
        let t = Instant::now();
        let err = driver
            .exchange(spec(&pool, "slow", Duration::from_millis(150)))
            .expect_err("deadline must expire");
        assert_eq!(err.kind(), io::ErrorKind::TimedOut);
        assert!(t.elapsed() < Duration::from_secs(2));
        assert_eq!(driver.deadlines_expired(), 1);
        assert_eq!(pool.idle_count(), 0, "expired sockets are not pooled");
    }

    /// A socket whose server side already hung up — exchanges on it
    /// fail immediately, exercising the pooled-failure retry path.
    fn stale_socket() -> (TcpStream, TcpListener) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let s = TcpStream::connect(l.local_addr().unwrap()).unwrap();
        let (server_side, _) = l.accept().unwrap();
        drop(server_side); // immediate close: s is now stale
        s.set_nonblocking(true).unwrap();
        (s, l)
    }

    #[test]
    fn stale_pooled_socket_retries_once_on_fresh_connection() {
        let addr = echo_server(Duration::ZERO, 1);
        let driver = NetDriver::start().unwrap();
        let live = Arc::new(ConnPool::new(addr, 2));
        let (stale, _keep) = stale_socket();
        live.put_back(stale);
        let reply = driver
            .exchange(spec(&live, "revived", Duration::from_secs(10)))
            .expect("fresh-connection retry must succeed");
        assert_eq!(reply, "[revived]");
    }

    #[test]
    fn stale_pool_plus_dead_backend_fails_and_clears_the_pool() {
        let dead_addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let driver = NetDriver::start().unwrap();
        let dead_pool = Arc::new(ConnPool::new(dead_addr, 2));
        let (stale, _keep) = stale_socket();
        dead_pool.put_back(stale);
        let err = driver
            .exchange(spec(&dead_pool, "q", Duration::from_secs(2)))
            .expect_err("stale pool + dead backend must fail");
        assert_eq!(dead_pool.idle_count(), 0, "stale pool was cleared");
        assert_ne!(
            err.kind(),
            io::ErrorKind::TimedOut,
            "failure should be a connect refusal, got {err}"
        );
    }

    #[test]
    fn connect_refused_is_not_a_deadline_expiry() {
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let driver = NetDriver::start().unwrap();
        let pool = Arc::new(ConnPool::new(dead, 1));
        let err = driver
            .exchange(spec(&pool, "q", Duration::from_secs(5)))
            .expect_err("nothing listens there");
        assert_ne!(err.kind(), io::ErrorKind::TimedOut, "{err}");
        assert_eq!(driver.deadlines_expired(), 0);
    }
}
