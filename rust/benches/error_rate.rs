//! Reproduces the **§4.5.1 error analysis**: 1024-bucket x 4-slot filter,
//! entity counts swept through the paper's 3,148 (load 0.7686), counting
//! fingerprint-collision shadowing and foreign false positives.
//!
//! Run: `cargo bench --bench error_rate`. Writes `results/error_rate.csv`.

use cft_rag::bench::experiments::error_rate;
use cft_rag::util::cli::{spec, Args};

fn main() {
    let args = Args::from_env(vec![
        spec(
            "entities",
            "comma-separated entity counts",
            Some("500,1000,2000,3148,3900"),
            false,
        ),
        spec("out", "CSV output path", Some("results/error_rate.csv"), false),
        spec("bench", "ignored (cargo bench passes it)", None, true),
    ])
    .unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    if args.wants_help() {
        println!("{}", args.usage());
        return;
    }
    let counts: Vec<usize> = args.list_or("entities", &[500, 1000, 2000, 3148, 3900]);
    let csv = error_rate(&counts);
    let out = args.str_or("out", "results/error_rate.csv");
    csv.write_to(&out).expect("write csv");
    println!("\nwrote {out}");
}
