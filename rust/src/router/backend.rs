//! One routed backend: a TCP coordinator address plus its connection
//! pool and health state. A backend owns the single-request round trip
//! (`line out, JSON line back`) — executed on the router's shared
//! outbound reactor ([`NetDriver`]) under a true **end-to-end
//! deadline** (connect + write + full reply =
//! `RouterConfig::request_timeout`) — including the
//! stale-pooled-connection retry policy; the scatter layer composes
//! these into fan-outs and failover. Probes are **epoch-gated**: a
//! `\x01stats` reply whose `partition_epoch` the router's [`EpochGate`]
//! rejects counts as a probe *failure*, so a backend mid-warm-up or
//! running a stale partition is never (re-)admitted early.
//!
//! # Examples
//!
//! ```
//! use std::sync::Arc;
//! use cft_rag::rag::config::RouterConfig;
//! use cft_rag::reactor::client::NetDriver;
//! use cft_rag::router::backend::Backend;
//! use cft_rag::router::health::EpochGate;
//!
//! let cfg = RouterConfig::for_backends(["127.0.0.1:7181"]);
//! let driver = Arc::new(NetDriver::start().unwrap());
//! let b = Backend::new(
//!     0,
//!     "127.0.0.1:7181",
//!     &cfg,
//!     Arc::new(EpochGate::new(0)),
//!     driver,
//! );
//! assert_eq!(b.addr(), "127.0.0.1:7181");
//! assert!(b.health().is_healthy(), "backends start optimistic");
//! ```

use std::io;
use std::time::{Duration, Instant};

use crate::coordinator::tcp::STATS_REQUEST;
use crate::rag::config::RouterConfig;
use crate::reactor::client::{Exchange, NetDriver};
use crate::router::health::{EpochGate, HealthState};
use crate::router::pool::ConnPool;
use crate::sync::Arc;
use crate::util::json::Json;
use crate::util::log;

/// Deadline stand-in for a zero (= "no timeout") request timeout:
/// far enough out to be unbounded in practice while keeping the
/// driver's timer arithmetic finite.
const NO_TIMEOUT: Duration = Duration::from_secs(24 * 60 * 60);

/// A backend coordinator behind the router.
#[derive(Debug)]
pub struct Backend {
    index: usize,
    pool: Arc<ConnPool>,
    /// The router's shared outbound reactor: every exchange — query,
    /// probe, rebalance wire op — multiplexes onto its one thread.
    driver: Arc<NetDriver>,
    health: HealthState,
    /// The membership epochs the router currently accepts — shared
    /// fleet-wide, consulted by [`probe`](Backend::probe).
    epoch_gate: Arc<EpochGate>,
    /// True when the router runs **without** a prober
    /// (`probe_interval == 0`): query-path successes then re-admit a
    /// demoted backend directly — with no prober, nothing else ever
    /// would, and no prober also means epoch staleness could never
    /// have been detected, so the gate is vacuous in that deployment.
    passive_readmit: bool,
    connect_timeout: Duration,
    request_timeout: Duration,
}

impl Backend {
    /// Backend `index` at `addr`, with the router config's deadlines,
    /// probing against the fleet's shared `epoch_gate`, exchanging
    /// over the shared outbound reactor `driver`.
    pub fn new(
        index: usize,
        addr: &str,
        cfg: &RouterConfig,
        epoch_gate: Arc<EpochGate>,
        driver: Arc<NetDriver>,
    ) -> Backend {
        Backend {
            index,
            pool: Arc::new(ConnPool::new(addr, cfg.max_idle_conns)),
            driver,
            health: HealthState::new(cfg.failure_threshold),
            epoch_gate,
            passive_readmit: cfg.probe_interval.is_zero(),
            connect_timeout: cfg.connect_timeout,
            request_timeout: cfg.request_timeout,
        }
    }

    /// Position in the router's backend list (= ring index).
    pub fn index(&self) -> usize {
        self.index
    }

    /// Backend address.
    pub fn addr(&self) -> &str {
        self.pool.addr()
    }

    /// Health state (shared with the prober and the scatter path).
    pub fn health(&self) -> &HealthState {
        &self.health
    }

    /// One request/reply round trip.
    ///
    /// The exchange runs on the outbound reactor under an absolute
    /// end-to-end deadline (`request_timeout` from the first byte of
    /// connect to the last byte of the reply). At most **one** pooled
    /// connection is tried before the driver falls through to a
    /// *fresh* connection within the same deadline — so a hung backend
    /// costs this attempt at most one request timeout, never
    /// timeout-per-idle-socket — and a pooled failure discards the
    /// whole idle pool (its siblings are from the same era and equally
    /// suspect). The fresh connection's outcome is authoritative:
    /// success resets the health failure streak, failure counts toward
    /// demotion. The reply being parseable JSON is part of "success" —
    /// a backend speaking garbage is as unusable as a dead one. When
    /// the router runs a prober, a success here does **not** re-admit
    /// a marked-down backend: query replies carry no partition epoch,
    /// so re-admission is reserved for the epoch-validating
    /// [`probe`](Backend::probe) — otherwise one answered query on the
    /// failover tail would bypass the [`EpochGate`] and route traffic
    /// to a backend serving a stale key slice. With probing disabled
    /// (`probe_interval == 0`) a success re-admits directly, as before
    /// the gate existed — nothing else ever would.
    pub fn request(&self, line: &str) -> io::Result<Json> {
        let raw = self.driver.exchange(self.exchange_spec(line));
        self.finish_exchange(raw)
    }

    /// The driver spec for one round trip to this backend — the
    /// scatter layer uses this to batch many backends' exchanges into
    /// a single multiplexed [`NetDriver::exchange_many`] call. The
    /// deadline clock starts *now*.
    pub(crate) fn exchange_spec(&self, line: &str) -> Exchange {
        let budget = if self.request_timeout.is_zero() {
            NO_TIMEOUT
        } else {
            self.request_timeout
        };
        Exchange {
            pool: Arc::clone(&self.pool),
            line: line.to_string(),
            connect_timeout: self.connect_timeout,
            deadline: Instant::now() + budget,
        }
    }

    /// Turn one driver reply into the request outcome — parse plus the
    /// same health accounting as [`request`](Backend::request) (which
    /// is implemented on top of this).
    pub(crate) fn finish_exchange(
        &self,
        raw: io::Result<String>,
    ) -> io::Result<Json> {
        let out = raw.and_then(|reply| self.parse_reply(&reply));
        match &out {
            Ok(_) => {
                if self.passive_readmit {
                    self.on_success();
                } else {
                    self.health.record_success();
                }
            }
            Err(e) => self.on_failure(e),
        }
        out
    }

    fn parse_reply(&self, reply: &str) -> io::Result<Json> {
        Json::parse(reply.trim()).map_err(|e| {
            io::Error::new(
                io::ErrorKind::InvalidData,
                format!("bad reply from {}: {e}", self.addr()),
            )
        })
    }

    /// Health probe: a `\x01stats` round trip. A reply only counts as
    /// healthy when it parses as JSON **and** reports a
    /// `partition_epoch` the router's [`EpochGate`] accepts (absent =
    /// epoch 0, the pre-elastic wire format) — a backend mid-warm-up or
    /// serving a stale partition keeps failing probes and is not
    /// re-admitted early. On success the reply's `requests` gauge is
    /// recorded as the backend's observed load.
    pub fn probe(&self) -> io::Result<Json> {
        let spec = self.probe_spec();
        self.finish_probe(self.driver.exchange(spec))
    }

    /// The wire half of [`probe`](Backend::probe), for fleet-batched
    /// probing ([`probe_fleet`]): counts the probe and returns its
    /// `\x01stats` exchange. Pair every spec with a
    /// [`finish_probe`](Backend::finish_probe) on the driver's reply.
    pub(crate) fn probe_spec(&self) -> Exchange {
        self.health.record_probe();
        self.exchange_spec(STATS_REQUEST)
    }

    /// The validation half of [`probe`](Backend::probe): parse the raw
    /// driver reply, epoch-gate it, record load, settle health.
    pub(crate) fn finish_probe(
        &self,
        raw: io::Result<String>,
    ) -> io::Result<Json> {
        let json = match raw.and_then(|reply| self.parse_reply(&reply)) {
            Ok(json) => json,
            Err(e) => {
                self.on_failure(&e);
                return Err(e);
            }
        };
        let epoch = json
            .get("partition_epoch")
            .and_then(Json::as_f64)
            .unwrap_or(0.0) as u64;
        if !self.epoch_gate.accepts(epoch) {
            let e = io::Error::new(
                io::ErrorKind::InvalidData,
                format!(
                    "{} serves partition epoch {epoch}, ring is at {}",
                    self.addr(),
                    self.epoch_gate.current()
                ),
            );
            self.on_failure(&e);
            return Err(e);
        }
        if let Some(r) = json.get("requests").and_then(Json::as_f64) {
            self.health.record_load(r as u64);
        }
        self.on_success();
        Ok(json)
    }

    fn on_success(&self) {
        if self.health.mark_success() {
            self.health.record_readmission();
            log::info!("backend {} re-admitted", self.addr());
        }
    }

    fn on_failure(&self, e: &io::Error) {
        if self.health.mark_failure() {
            log::warn!("backend {} marked unhealthy: {e}", self.addr());
            // a down backend's idle sockets are suspect too
            self.pool.clear();
        }
    }
}

/// Probe a whole fleet in one multiplexed round: every backend's
/// `\x01stats` exchange flies concurrently on the shared outbound
/// reactor, so a probe round costs at most one request deadline even
/// when several backends hang — sequential [`Backend::probe`] calls
/// would stack a deadline per hung backend. Relies on the router
/// invariant that every backend shares one driver (`Router::connect`
/// builds the fleet that way, and joiners inherit it).
pub fn probe_fleet(backends: &[Arc<Backend>]) {
    let Some(first) = backends.first() else { return };
    let specs = backends.iter().map(|b| b.probe_spec()).collect();
    let results = first.driver.exchange_many(specs);
    for (b, (raw, _)) in backends.iter().zip(results) {
        // outcome lands in the backend's HealthState; a failed probe
        // is the demotion signal itself
        let _ = b.finish_probe(raw);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpListener;

    fn cfg() -> RouterConfig {
        RouterConfig {
            connect_timeout: Duration::from_millis(300),
            request_timeout: Duration::from_millis(500),
            ..RouterConfig::default()
        }
    }

    fn backend(addr: &str) -> Backend {
        Backend::new(
            0,
            addr,
            &cfg(),
            Arc::new(EpochGate::new(0)),
            Arc::new(NetDriver::start().unwrap()),
        )
    }

    /// One-shot echo server speaking the line protocol with a fixed
    /// JSON reply per line received.
    fn fake_backend(reply: &'static str, conns: usize) -> String {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            for _ in 0..conns {
                let Ok((stream, _)) = listener.accept() else { return };
                std::thread::spawn(move || {
                    let mut reader = BufReader::new(stream.try_clone().unwrap());
                    let mut writer = stream;
                    let mut line = String::new();
                    while reader.read_line(&mut line).unwrap_or(0) > 0 {
                        writer.write_all(reply.as_bytes()).unwrap();
                        writer.write_all(b"\n").unwrap();
                        line.clear();
                    }
                });
            }
        });
        addr
    }

    #[test]
    fn request_roundtrips_and_pools() {
        let addr = fake_backend(r#"{"ok":true,"answer":"x"}"#, 1);
        let b = backend(&addr);
        let json = b.request("hello").unwrap();
        assert_eq!(json.get("ok"), Some(&Json::Bool(true)));
        // second request reuses the pooled connection (the fake server
        // accepts exactly one)
        let json = b.request("again").unwrap();
        assert_eq!(json.get("answer").and_then(Json::as_str), Some("x"));
        assert!(b.health().is_healthy());
    }

    #[test]
    fn garbage_reply_is_a_failure() {
        let addr = fake_backend("not json at all", 2);
        let b = backend(&addr);
        let err = b.request("q").expect_err("unparseable reply");
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(!b.health().is_healthy(), "threshold 1: marked down");
    }

    #[test]
    fn dead_backend_fails_and_stays_down() {
        // a port with nothing listening
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let b = backend(&addr);
        assert!(b.request("q").is_err());
        assert!(!b.health().is_healthy());
        // nothing came back up: stays down
        assert!(b.request("q").is_err());
        assert!(!b.health().is_healthy());
        assert_eq!(b.health().readmissions(), 0);
    }

    #[test]
    fn hung_backend_times_out_at_the_request_deadline() {
        // a listener that accepts and then never replies: only the
        // end-to-end deadline (not a per-stream socket timeout) can
        // bound this request
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let hold = std::thread::spawn(move || listener.accept());
        let b = backend(&addr);
        let started = Instant::now();
        let err = b.request("q").expect_err("nothing ever replies");
        assert_eq!(err.kind(), io::ErrorKind::TimedOut, "{err}");
        let waited = started.elapsed();
        assert!(
            waited >= Duration::from_millis(400)
                && waited < Duration::from_secs(5),
            "deadline ~500ms, waited {waited:?}"
        );
        assert!(!b.health().is_healthy());
        drop(hold);
    }

    #[test]
    fn probe_records_backend_load() {
        let addr = fake_backend(r#"{"requests":7,"failures":0}"#, 1);
        let b = backend(&addr);
        let json = b.probe().unwrap();
        assert_eq!(json.get("requests").and_then(Json::as_f64), Some(7.0));
        assert_eq!(b.health().observed_load(), 7);
        assert_eq!(b.health().probes(), 1);
    }

    #[test]
    fn proberless_router_readmits_on_query_success() {
        // With probe_interval == 0 there is no prober to ever call
        // probe(), so the pre-gate behavior must survive: a successful
        // query re-admits a passively demoted backend.
        let addr = fake_backend(r#"{"ok":true}"#, 2);
        let cfg = RouterConfig {
            probe_interval: Duration::ZERO,
            ..cfg()
        };
        let driver = Arc::new(NetDriver::start().unwrap());
        let gate = Arc::new(EpochGate::new(0));
        let b = Backend::new(0, &addr, &cfg, gate.clone(), driver.clone());
        // demote via a failure against a dead port first
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let down = Backend::new(0, &dead, &cfg, gate, driver);
        assert!(down.request("q").is_err());
        assert!(!down.health().is_healthy());
        // the live backend: force a demotion, then one success re-admits
        b.health().mark_failure();
        assert!(!b.health().is_healthy());
        assert!(b.request("q").is_ok());
        assert!(
            b.health().is_healthy(),
            "probe-less routers must re-admit on query success"
        );
        assert!(b.health().readmissions() >= 1);
    }

    #[test]
    fn probe_rejects_stale_partition_epoch() {
        // The backend answers stats happily — but for membership epoch
        // 0 while the ring has moved to 2. The probe must count that as
        // a FAILURE (no early admission of a stale or mid-warm-up
        // backend), and must not refresh the load gauge either.
        let addr = fake_backend(
            r#"{"requests":9,"failures":0,"partition_epoch":0}"#,
            4,
        );
        let gate = Arc::new(EpochGate::new(2));
        let b = Backend::new(
            0,
            &addr,
            &cfg(),
            gate.clone(),
            Arc::new(NetDriver::start().unwrap()),
        );
        let err = b.probe().expect_err("stale epoch must fail the probe");
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("epoch"), "{err}");
        assert!(!b.health().is_healthy(), "threshold 1: marked down");
        assert_eq!(b.health().observed_load(), 0, "stale load not recorded");
        // plain requests still work, but an answered query must NOT
        // re-admit a backend demoted for a stale epoch (query replies
        // carry no epoch to validate)...
        assert!(b.request("\u{1}stats").is_ok());
        assert!(
            !b.health().is_healthy(),
            "query-path success must not bypass the epoch gate"
        );
        // ...and once the gate accepts the backend's epoch (a rebalance
        // opened epoch 0→2 coexistence, or the backend caught up), the
        // probe re-admits it and records load.
        gate.open(0);
        let json = b.probe().expect("accepted epoch probes clean");
        assert_eq!(json.get("partition_epoch").and_then(Json::as_f64), Some(0.0));
        assert_eq!(b.health().observed_load(), 9);
        assert!(b.health().is_healthy());
        assert!(b.health().readmissions() >= 1);
    }
}
