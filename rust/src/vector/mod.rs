//! Vector search stage (Figure 1, step 1): document store sharded to the
//! score artifact's shape + top-k similarity search.

pub mod search;
pub mod store;

pub use search::{search_topk, Hit};
pub use store::VectorStore;
