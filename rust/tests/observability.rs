//! End-to-end observability: request tracing across the router → backend
//! TCP hop, the `\x01trace` span-tree export (including the ≥95%%
//! wall-time coverage acceptance bar), the `\x01metrics` Prometheus
//! text-exposition lint, wire compatibility for old-style peers, and a
//! registry concurrency smoke over the `sync` shim primitives so the
//! modelcheck scheduler can drive it too.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use cft_rag::coordinator::tcp::{serve_listener, ServeHandle};
use cft_rag::coordinator::{Coordinator, CoordinatorConfig};
use cft_rag::data::corpus::corpus_from_texts;
use cft_rag::data::hospital::{HospitalConfig, HospitalDataset};
use cft_rag::obs::registry::Registry;
use cft_rag::obs::trace::{self, Stage, STAGES};
use cft_rag::rag::config::{RagConfig, RouterConfig};
use cft_rag::router::Router;
use cft_rag::runtime::engine::{Engine, NativeEngine};
use cft_rag::sync::Arc;
use cft_rag::util::json::Json;

/// One in-process backend: a coordinator behind a real TCP listener.
struct TestBackend {
    coordinator: Arc<Coordinator>,
    handle: Option<ServeHandle>,
    addr: String,
}

impl TestBackend {
    fn start(ds: &HospitalDataset, cfg: RagConfig) -> TestBackend {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let forest = Arc::new(ds.build_forest());
        let engine: Arc<dyn Engine> = Arc::new(NativeEngine::new());
        let coordinator = Arc::new(
            Coordinator::start(
                forest,
                corpus_from_texts(&ds.documents()),
                engine,
                cfg,
                CoordinatorConfig { workers: 2, ..Default::default() },
            )
            .expect("backend coordinator"),
        );
        let handle = serve_listener(coordinator.clone(), listener)
            .expect("backend listener");
        let addr = handle.addr().to_string();
        TestBackend { coordinator, handle: Some(handle), addr }
    }

    fn kill(&mut self) {
        if let Some(h) = self.handle.take() {
            h.shutdown();
        }
        self.coordinator.stop();
    }
}

impl Drop for TestBackend {
    fn drop(&mut self) {
        self.kill();
    }
}

fn dataset() -> HospitalDataset {
    HospitalDataset::generate(HospitalConfig {
        trees: 4,
        ..HospitalConfig::default()
    })
}

/// One request/reply roundtrip on an already-open connection.
fn roundtrip(conn: &mut BufReader<TcpStream>, line: &str) -> Json {
    conn.get_mut()
        .write_all(format!("{line}\n").as_bytes())
        .expect("write request");
    let mut reply = String::new();
    conn.read_line(&mut reply).expect("read reply");
    Json::parse(&reply).unwrap_or_else(|e| panic!("bad reply {reply:?}: {e}"))
}

fn connect(addr: &str) -> BufReader<TcpStream> {
    let s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    BufReader::new(s)
}

fn is_ok(reply: &Json) -> bool {
    reply.get("ok") == Some(&Json::Bool(true))
}

/// A sampled trace id handed to the router must cross the TCP hop as a
/// `\x01t=` line prefix and be adopted by the backend — provable
/// because backend-side stages (batching, retrieval) can only land
/// under this id if the backend learned it from the wire.
#[test]
fn trace_id_propagates_from_router_to_backend() {
    let ds = dataset();
    let backend =
        TestBackend::start(&ds, RagConfig::default());
    let names: Vec<String> = ds
        .build_forest()
        .interner()
        .iter()
        .map(|(_, n)| n.to_string())
        .collect();
    let router = Router::connect(
        names.iter().map(String::as_str),
        &RouterConfig {
            probe_interval: Duration::ZERO,
            ..RouterConfig::for_backends(vec![backend.addr.clone()])
        },
    )
    .expect("router");

    let trace = trace::mint();
    let reply =
        router.query_traced("what is the parent unit of cardiology", trace);
    assert!(is_ok(&reply), "{reply}");

    let stages: Vec<&str> =
        trace::spans_for(trace).iter().map(|s| s.stage.name()).collect();
    // router side of the hop
    assert!(stages.contains(&Stage::Exchange.name()), "{stages:?}");
    // backend side: only reachable through the wire prefix
    assert!(stages.contains(&Stage::Retrieval.name()), "{stages:?}");
    assert!(stages.contains(&Stage::EmbedSearch.name()), "{stages:?}");
}

/// The front-door acceptance bar: a traced query's span tree names
/// every stage with non-negative durations and the union of its child
/// spans covers ≥ 95%% of the front door's measured wall time.
#[test]
fn trace_export_names_stages_and_covers_wall_time() {
    let ds = dataset();
    let backend = TestBackend::start(
        &ds,
        RagConfig { trace_sample_every: 1, ..RagConfig::default() },
    );
    let mut conn = connect(&backend.addr);

    let reply = roundtrip(&mut conn, "what is the parent unit of cardiology");
    assert!(is_ok(&reply), "{reply}");
    let id = reply
        .get("trace")
        .and_then(Json::as_str)
        .expect("sampled reply carries its trace id")
        .to_string();

    let export = roundtrip(&mut conn, &format!("\x01trace {id}"));
    assert!(is_ok(&export), "{export}");
    let traces = export.get("traces").and_then(Json::as_arr).expect("traces");
    assert_eq!(traces.len(), 1, "{export}");
    let t = &traces[0];
    assert_eq!(t.get("id").and_then(Json::as_str), Some(id.as_str()));
    assert_eq!(t.get("door").and_then(Json::as_str), Some("coordinator"));

    let known: Vec<&str> = STAGES.iter().map(|s| s.name()).collect();
    let spans = t.get("spans").and_then(Json::as_arr).expect("spans");
    assert!(!spans.is_empty(), "{export}");
    for s in spans {
        let stage = s.get("stage").and_then(Json::as_str).expect("stage");
        assert!(known.contains(&stage), "unknown stage {stage}");
        assert!(
            s.get("dur_us").and_then(Json::as_f64).unwrap_or(-1.0) >= 0.0,
            "negative duration: {s}"
        );
        assert!(
            s.get("start_us").and_then(Json::as_f64).unwrap_or(-1.0) >= 0.0,
            "span starts before its root: {s}"
        );
    }
    // the tree must explain where the request's wall time went
    let coverage =
        t.get("coverage").and_then(Json::as_f64).expect("coverage");
    assert!(
        coverage >= 0.95,
        "span tree covers {:.1}% of front-door wall time: {t}",
        coverage * 100.0
    );
}

/// `\x01metrics` must emit parseable Prometheus text exposition: every
/// series typed, histogram buckets cumulative and `+Inf`-terminated,
/// `_count` agreeing with the `+Inf` bucket.
#[test]
fn metrics_exposition_is_well_formed() {
    let ds = dataset();
    let backend = TestBackend::start(
        &ds,
        RagConfig { trace_sample_every: 1, ..RagConfig::default() },
    );
    let mut conn = connect(&backend.addr);
    for _ in 0..3 {
        assert!(is_ok(&roundtrip(
            &mut conn,
            "what is the parent unit of cardiology"
        )));
    }

    let reply = roundtrip(&mut conn, "\x01metrics");
    assert!(is_ok(&reply), "{reply}");
    assert_eq!(
        reply.get("content_type").and_then(Json::as_str),
        Some("text/plain; version=0.0.4")
    );
    let text = reply.get("text").and_then(Json::as_str).expect("text");
    assert!(text.contains("cft_coordinator_requests_total"), "{text}");

    let mut typed: Vec<(String, String)> = Vec::new(); // (name, type)
    let mut hist: std::collections::BTreeMap<String, (Vec<f64>, Vec<f64>)> =
        std::collections::BTreeMap::new(); // name -> (les, counts)
    let mut counts: std::collections::BTreeMap<String, f64> =
        std::collections::BTreeMap::new();
    for line in text.lines() {
        if line.is_empty() || line.starts_with("# HELP") {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().expect("typed name").to_string();
            let kind = it.next().expect("type kind").to_string();
            assert!(
                ["counter", "gauge", "histogram"].contains(&kind.as_str()),
                "{line}"
            );
            typed.push((name, kind));
            continue;
        }
        // sample line: name[{labels}] value
        let (series, value) =
            line.rsplit_once(' ').unwrap_or_else(|| panic!("bad line {line}"));
        let value: f64 = value
            .parse()
            .unwrap_or_else(|e| panic!("bad value in {line}: {e}"));
        let (name, label) = match series.split_once('{') {
            Some((n, l)) => (n, Some(l.trim_end_matches('}'))),
            None => (series, None),
        };
        // every sample belongs to a typed family (suffixes fold back)
        let family = name
            .strip_suffix("_bucket")
            .or_else(|| name.strip_suffix("_sum"))
            .or_else(|| name.strip_suffix("_count"))
            .filter(|f| typed.iter().any(|(n, k)| n == f && k == "histogram"))
            .unwrap_or(name);
        assert!(
            typed.iter().any(|(n, _)| n == family),
            "untyped series {name} in {line}"
        );
        if let Some(bucket) = name.strip_suffix("_bucket") {
            let le = label
                .and_then(|l| l.strip_prefix("le=\""))
                .map(|l| l.trim_end_matches('"'))
                .unwrap_or_else(|| panic!("bucket without le: {line}"));
            let le = if le == "+Inf" {
                f64::INFINITY
            } else {
                le.parse().unwrap_or_else(|e| panic!("bad le {le}: {e}"))
            };
            let entry = hist.entry(bucket.to_string()).or_default();
            entry.0.push(le);
            entry.1.push(value);
        } else if let Some(base) = name.strip_suffix("_count") {
            counts.insert(base.to_string(), value);
        }
    }
    assert!(
        typed.iter().any(|(_, k)| k == "histogram"),
        "request latency histogram missing: {text}"
    );
    for (name, (les, bucket_counts)) in &hist {
        assert_eq!(
            les.last().copied(),
            Some(f64::INFINITY),
            "{name}: buckets must end at +Inf"
        );
        assert!(
            les.windows(2).all(|w| w[0] < w[1]),
            "{name}: le bounds must increase: {les:?}"
        );
        assert!(
            bucket_counts.windows(2).all(|w| w[0] <= w[1]),
            "{name}: buckets must be cumulative: {bucket_counts:?}"
        );
        assert_eq!(
            counts.get(name).copied(),
            bucket_counts.last().copied(),
            "{name}: _count must equal the +Inf bucket"
        );
    }
}

/// Wire compatibility: peers that have never heard of tracing keep
/// working — plain query lines, the unprefixed `\x01stats` verb, and
/// the old reply shape (no `trace` field) when sampling is off; a
/// malformed `\x01t=` prefix is rejected the way any unknown control
/// verb always was.
#[test]
fn old_style_lines_still_parse() {
    let ds = dataset();
    let backend = TestBackend::start(&ds, RagConfig::default());
    let mut conn = connect(&backend.addr);

    let reply = roundtrip(&mut conn, "what is the parent unit of cardiology");
    assert!(is_ok(&reply), "{reply}");
    assert_eq!(reply.get("trace"), None, "unsampled replies stay old-shape");

    let stats = roundtrip(&mut conn, "\x01stats");
    assert!(is_ok(&stats), "{stats}");
    for field in ["requests", "total_p99_s", "uptime_s", "version"] {
        assert!(stats.get(field).is_some(), "{field} missing: {stats}");
    }

    // a mangled trace prefix (non-hex id) must NOT be half-understood:
    // it falls through to the control parser as an unknown verb
    let reply = roundtrip(&mut conn, "\x01t=nothexatall \x01stats");
    assert_eq!(reply.get("ok"), Some(&Json::Bool(false)), "{reply}");
}

/// The registry primitives under concurrent writers, built on the
/// `sync` shim's thread spawn so the deterministic modelcheck
/// scheduler can interleave it when the feature is on.
#[test]
fn registry_counters_and_histograms_under_concurrency() {
    const THREADS: u64 = 4;
    const PER_THREAD: u64 = 1000;
    let registry = Arc::new(Registry::new());
    let counter = registry.counter("smoke_total", "concurrency smoke");
    let hist = registry.histogram("smoke_seconds", "concurrency smoke");

    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let counter = Arc::clone(&counter);
            let hist = Arc::clone(&hist);
            cft_rag::sync::thread::spawn(move || {
                for i in 0..PER_THREAD {
                    counter.inc();
                    hist.record((t * PER_THREAD + i) as f64 * 1e-6);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("writer thread");
    }

    assert_eq!(counter.get(), THREADS * PER_THREAD);
    assert_eq!(hist.count(), THREADS * PER_THREAD);
    assert!(hist.sum() > 0.0);
    let p99 = hist.quantile(0.99);
    assert!(p99 > 0.0 && p99 <= hist.quantile(1.0) * 1.5 + 1e-9);
    let text = registry.render();
    assert!(text.contains("# TYPE smoke_total counter"), "{text}");
    assert!(text.contains("smoke_seconds_bucket"), "{text}");
}
