//! Adversarial front-door serving tests over the REAL stack: slow
//! (dribbling) clients, half-closes, mid-line disconnects, and
//! connection-cap overload against both the coordinator's and the
//! router's nonblocking reactor front doors (`coordinator/tcp.rs`,
//! `router/mod.rs`). The reactor engine has unit tests for the same
//! attacks in isolation (`reactor/server.rs`); these prove the wiring —
//! config knobs reaching the reactor, `\x01stats` gauges reporting what
//! happened, honest clients staying served throughout.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use cft_rag::coordinator::tcp::serve_listener;
use cft_rag::coordinator::{Coordinator, CoordinatorConfig};
use cft_rag::data::corpus::corpus_from_texts;
use cft_rag::data::hospital::{HospitalConfig, HospitalDataset};
use cft_rag::rag::config::{RagConfig, RouterConfig};
use cft_rag::router::{serve_listener as router_serve_listener, Router};
use cft_rag::runtime::engine::{Engine, NativeEngine};
use cft_rag::util::json::Json;
use cft_rag::util::wait::{require, wait_until};

const SECS_10: Duration = Duration::from_secs(10);

fn dataset() -> HospitalDataset {
    HospitalDataset::generate(HospitalConfig {
        trees: 3,
        ..HospitalConfig::default()
    })
}

fn coordinator(cfg: RagConfig) -> Arc<Coordinator> {
    let ds = dataset();
    let forest = Arc::new(ds.build_forest());
    let engine: Arc<dyn Engine> = Arc::new(NativeEngine::new());
    Arc::new(
        Coordinator::start(
            forest,
            corpus_from_texts(&ds.documents()),
            engine,
            cfg,
            CoordinatorConfig { workers: 2, ..Default::default() },
        )
        .unwrap(),
    )
}

/// One fresh-connection line exchange; `None` on any refusal or IO
/// failure — the polling predicate for "the front door serves again".
fn roundtrip(addr: &SocketAddr, line: &str) -> Option<String> {
    let mut conn = TcpStream::connect(addr).ok()?;
    conn.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    conn.write_all(format!("{line}\n").as_bytes()).ok()?;
    let mut reply = String::new();
    BufReader::new(conn).read_line(&mut reply).ok()?;
    (!reply.is_empty()).then(|| reply.trim().to_string())
}

fn stats_served(addr: &SocketAddr) -> bool {
    roundtrip(addr, "\x01stats").is_some_and(|l| l.contains("\"requests\""))
}

#[test]
fn coordinator_overload_is_refused_cleanly_and_recovers() {
    let c = coordinator(RagConfig {
        max_connections: 1,
        ..RagConfig::default()
    });
    let handle =
        serve_listener(c.clone(), TcpListener::bind("127.0.0.1:0").unwrap())
            .unwrap();
    let addr = handle.addr();

    // fill the single admitted slot and prove it serves
    let mut admitted = TcpStream::connect(addr).unwrap();
    admitted
        .set_read_timeout(Some(Duration::from_secs(5)))
        .unwrap();
    admitted.write_all(b"\x01stats\n").unwrap();
    let mut admitted = BufReader::new(admitted);
    let mut line = String::new();
    admitted.read_line(&mut line).unwrap();
    assert!(line.contains("\"requests\""), "{line}");

    // the connection over the cap gets one refusal line, then EOF —
    // never a hang, never a silent drop
    let refused = TcpStream::connect(addr).unwrap();
    refused
        .set_read_timeout(Some(Duration::from_secs(5)))
        .unwrap();
    let mut refused = BufReader::new(refused);
    line.clear();
    refused.read_line(&mut line).unwrap();
    let reply = Json::parse(line.trim()).expect("refusal is a JSON line");
    assert_eq!(reply.get("ok"), Some(&Json::Bool(false)), "{reply}");
    assert_eq!(
        reply.get("error").and_then(Json::as_str),
        Some("overloaded"),
        "{reply}"
    );
    line.clear();
    assert_eq!(refused.read_line(&mut line).unwrap(), 0, "refused conn EOF");
    assert!(handle.stats().overloaded_rejects() >= 1);

    // freeing the slot re-opens the door
    drop(admitted);
    require("a new client is admitted after the slot freed", SECS_10, || {
        stats_served(&addr)
    });
    handle.shutdown();
    c.stop();
}

#[test]
fn coordinator_slowloris_is_reaped_while_honest_clients_are_served() {
    let c = coordinator(RagConfig {
        idle_timeout: Duration::from_millis(150),
        ..RagConfig::default()
    });
    let handle =
        serve_listener(c.clone(), TcpListener::bind("127.0.0.1:0").unwrap())
            .unwrap();
    let addr = handle.addr();

    // the attack: bytes trickle in but a line never completes, so the
    // idle clock (keyed on *completed* lines) never advances
    let mut dribbler = TcpStream::connect(addr).unwrap();
    dribbler.write_all(b"\x01sta").unwrap();

    // an honest client is served while the dribbler squats
    assert!(stats_served(&addr));

    require("dribbler reaped by the idle timeout", SECS_10, || {
        handle.stats().idle_deadlines_expired() >= 1
    });

    // the reaped socket is genuinely dead from the client side
    dribbler
        .set_read_timeout(Some(Duration::from_millis(50)))
        .unwrap();
    let mut buf = [0u8; 16];
    let dead = wait_until(SECS_10, || match dribbler.read(&mut buf) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) => !matches!(
            e.kind(),
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
        ),
    });
    assert!(dead, "reaped connection reads EOF or reset");

    // the reap shows up in the stats payload, and serving continues
    let reply = roundtrip(&addr, "\x01stats").expect("still serving");
    let snap = Json::parse(&reply).unwrap();
    assert!(
        snap.get("idle_deadlines_expired").and_then(Json::as_f64)
            >= Some(1.0),
        "{snap}"
    );
    handle.shutdown();
    c.stop();
}

#[test]
fn coordinator_half_close_and_mid_line_disconnect_are_contained() {
    let c = coordinator(RagConfig::default());
    let handle =
        serve_listener(c.clone(), TcpListener::bind("127.0.0.1:0").unwrap())
            .unwrap();
    let addr = handle.addr();

    // half-close: a complete line plus a partial tail, then FIN. The
    // complete line is still answered; the tail is dropped silently.
    let mut conn = TcpStream::connect(addr).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    conn.write_all(b"\x01stats\n\x01sta").unwrap();
    conn.shutdown(Shutdown::Write).unwrap();
    let mut reader = BufReader::new(conn);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"requests\""), "answered after FIN: {line}");
    line.clear();
    assert_eq!(reader.read_line(&mut line).unwrap(), 0, "then EOF");

    // mid-line hard disconnect: partial line, socket vanishes
    let mut rude = TcpStream::connect(addr).unwrap();
    rude.write_all(b"describe the hierar").unwrap();
    drop(rude);

    require("server keeps serving after the disconnects", SECS_10, || {
        stats_served(&addr)
    });
    require("dead connections leave the open gauge", SECS_10, || {
        handle.stats().open_connections() == 0
    });
    handle.shutdown();
    c.stop();
}

#[test]
fn router_front_door_caps_reaps_and_survives_rude_clients() {
    let ds = dataset();
    let backend = coordinator(RagConfig::default());
    let backend_handle = serve_listener(
        backend.clone(),
        TcpListener::bind("127.0.0.1:0").unwrap(),
    )
    .unwrap();
    let names: Vec<String> = ds
        .build_forest()
        .interner()
        .iter()
        .map(|(_, n)| n.to_string())
        .collect();
    let cfg = RouterConfig {
        backends: vec![backend_handle.addr().to_string()],
        probe_interval: Duration::ZERO,
        max_connections: 1,
        idle_timeout: Duration::from_millis(200),
        ..RouterConfig::default()
    };
    let router = Arc::new(
        Router::connect(names.iter().map(String::as_str), &cfg).unwrap(),
    );
    let handle = router_serve_listener(
        router,
        TcpListener::bind("127.0.0.1:0").unwrap(),
    )
    .unwrap();
    let addr = handle.addr();

    // a real query runs the whole pipeline: front-door reactor →
    // dispatch worker → scatter → outbound reactor → backend reactor
    let mut admitted = TcpStream::connect(addr).unwrap();
    admitted
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    admitted
        .write_all(b"what is the parent unit of cardiology\n\x01stats\n")
        .unwrap();
    let mut admitted = BufReader::new(admitted);
    let mut line = String::new();
    admitted.read_line(&mut line).unwrap();
    let reply = Json::parse(line.trim()).expect("query reply is JSON");
    assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply}");
    // the pipelined stats line reports the front door's own gauges
    line.clear();
    admitted.read_line(&mut line).unwrap();
    let snap = Json::parse(line.trim()).expect("stats reply is JSON");
    assert_eq!(
        snap.get("open_connections").and_then(Json::as_f64),
        Some(1.0),
        "{snap}"
    );
    assert!(snap.get("ring_epoch").is_some(), "{snap}");
    assert!(snap.get("deadlines_expired").is_some(), "{snap}");

    // over the cap: clean overloaded refusal
    let refused = TcpStream::connect(addr).unwrap();
    refused
        .set_read_timeout(Some(Duration::from_secs(5)))
        .unwrap();
    let mut refused = BufReader::new(refused);
    line.clear();
    refused.read_line(&mut line).unwrap();
    let reply = Json::parse(line.trim()).unwrap();
    assert_eq!(
        reply.get("error").and_then(Json::as_str),
        Some("overloaded"),
        "{reply}"
    );
    assert!(handle.stats().overloaded_rejects() >= 1);

    // free the slot, then squat on it with a dribbler: reaped on the
    // idle timeout, and the door opens again
    drop(admitted);
    require("slot freed", SECS_10, || {
        handle.stats().open_connections() == 0
    });
    let mut dribbler = TcpStream::connect(addr).unwrap();
    dribbler.write_all(b"\x01sta").unwrap();
    require("router reaps the dribbler", SECS_10, || {
        handle.stats().idle_deadlines_expired() >= 1
    });

    // mid-line disconnect, then the front door still serves
    let mut rude = TcpStream::connect(addr).unwrap();
    rude.write_all(b"what is the par").unwrap();
    drop(rude);
    require("router serves after the rude clients", SECS_10, || {
        stats_served(&addr)
    });

    handle.shutdown();
    backend_handle.shutdown();
    backend.stop();
}
