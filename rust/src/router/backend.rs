//! One routed backend: a TCP coordinator address plus its connection
//! pool and health state. A backend owns the single-request round trip
//! (`line out, JSON line back`) including the stale-pooled-connection
//! retry policy; the scatter layer composes these into fan-outs and
//! failover.

use std::io::{self, BufRead, BufReader, Write};
use std::net::TcpStream;

use crate::coordinator::tcp::STATS_REQUEST;
use crate::rag::config::RouterConfig;
use crate::router::health::HealthState;
use crate::router::pool::ConnPool;
use crate::util::json::Json;
use crate::util::log;

/// A backend coordinator behind the router.
#[derive(Debug)]
pub struct Backend {
    index: usize,
    pool: ConnPool,
    health: HealthState,
}

impl Backend {
    /// Backend `index` at `addr`, with the router config's timeouts.
    pub fn new(index: usize, addr: &str, cfg: &RouterConfig) -> Backend {
        Backend {
            index,
            pool: ConnPool::new(
                addr,
                cfg.max_idle_conns,
                cfg.connect_timeout,
                cfg.request_timeout,
            ),
            health: HealthState::new(cfg.failure_threshold),
        }
    }

    /// Position in the router's backend list (= ring index).
    pub fn index(&self) -> usize {
        self.index
    }

    /// Backend address.
    pub fn addr(&self) -> &str {
        self.pool.addr()
    }

    /// Health state (shared with the prober and the scatter path).
    pub fn health(&self) -> &HealthState {
        &self.health
    }

    /// One request/reply round trip.
    ///
    /// At most **one** pooled connection is tried before falling
    /// through to a *fresh* connection — so a hung backend costs this
    /// attempt at most 2× the request timeout, never timeout-per-idle-
    /// socket — and a pooled failure discards the whole idle pool (its
    /// siblings are from the same era and equally suspect). The fresh
    /// connection's outcome is authoritative: success resets the health
    /// failure streak (re-admitting a marked-down backend), failure
    /// counts toward demotion. The reply being parseable JSON is part
    /// of "success" — a backend speaking garbage is as unusable as a
    /// dead one.
    pub fn request(&self, line: &str) -> io::Result<Json> {
        debug_assert!(!line.contains('\n'), "protocol is one line per request");
        if let Some(conn) = self.pool.take_idle() {
            match self.roundtrip(conn, line) {
                Ok(json) => {
                    self.on_success();
                    return Ok(json);
                }
                Err(e) => {
                    log::debug!(
                        "stale pooled connection to {}: {e}",
                        self.addr()
                    );
                    self.pool.clear();
                }
            }
        }
        match self.pool.connect().and_then(|conn| self.roundtrip(conn, line)) {
            Ok(json) => {
                self.on_success();
                Ok(json)
            }
            Err(e) => {
                if self.health.mark_failure() {
                    log::warn!("backend {} marked unhealthy: {e}", self.addr());
                    // a down backend's idle sockets are suspect too
                    self.pool.clear();
                }
                Err(e)
            }
        }
    }

    /// Health probe: a `\x01stats` round trip. On success the reply's
    /// `requests` gauge is recorded as the backend's observed load.
    pub fn probe(&self) -> io::Result<Json> {
        self.health.record_probe();
        let json = self.request(STATS_REQUEST)?;
        if let Some(r) = json.get("requests").and_then(Json::as_f64) {
            self.health.record_load(r as u64);
        }
        Ok(json)
    }

    fn on_success(&self) {
        if self.health.mark_success() {
            self.health.record_readmission();
            log::info!("backend {} re-admitted", self.addr());
        }
    }

    /// Write `line`, read one reply line, parse it; the connection goes
    /// back to the pool only after a fully clean round trip.
    fn roundtrip(&self, mut conn: TcpStream, line: &str) -> io::Result<Json> {
        conn.write_all(line.as_bytes())?;
        conn.write_all(b"\n")?;
        let mut reply = String::new();
        {
            let mut reader = BufReader::new(&conn);
            if reader.read_line(&mut reply)? == 0 {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    format!("{} closed before replying", self.addr()),
                ));
            }
        }
        let json = Json::parse(reply.trim()).map_err(|e| {
            io::Error::new(
                io::ErrorKind::InvalidData,
                format!("bad reply from {}: {e}", self.addr()),
            )
        })?;
        self.pool.put_back(conn);
        Ok(json)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;
    use std::time::Duration;

    fn cfg() -> RouterConfig {
        RouterConfig {
            connect_timeout: Duration::from_millis(300),
            request_timeout: Duration::from_millis(500),
            ..RouterConfig::default()
        }
    }

    /// One-shot echo server speaking the line protocol with a fixed
    /// JSON reply per line received.
    fn fake_backend(reply: &'static str, conns: usize) -> String {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            for _ in 0..conns {
                let Ok((stream, _)) = listener.accept() else { return };
                std::thread::spawn(move || {
                    let mut reader = BufReader::new(stream.try_clone().unwrap());
                    let mut writer = stream;
                    let mut line = String::new();
                    while reader.read_line(&mut line).unwrap_or(0) > 0 {
                        writer.write_all(reply.as_bytes()).unwrap();
                        writer.write_all(b"\n").unwrap();
                        line.clear();
                    }
                });
            }
        });
        addr
    }

    #[test]
    fn request_roundtrips_and_pools() {
        let addr = fake_backend(r#"{"ok":true,"answer":"x"}"#, 1);
        let b = Backend::new(0, &addr, &cfg());
        let json = b.request("hello").unwrap();
        assert_eq!(json.get("ok"), Some(&Json::Bool(true)));
        // second request reuses the pooled connection (the fake server
        // accepts exactly one)
        let json = b.request("again").unwrap();
        assert_eq!(json.get("answer").and_then(Json::as_str), Some("x"));
        assert!(b.health().is_healthy());
    }

    #[test]
    fn garbage_reply_is_a_failure() {
        let addr = fake_backend("not json at all", 2);
        let b = Backend::new(0, &addr, &cfg());
        let err = b.request("q").expect_err("unparseable reply");
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(!b.health().is_healthy(), "threshold 1: marked down");
    }

    #[test]
    fn dead_backend_fails_and_stays_down() {
        // a port with nothing listening
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let b = Backend::new(0, &addr, &cfg());
        assert!(b.request("q").is_err());
        assert!(!b.health().is_healthy());
        // nothing came back up: stays down
        assert!(b.request("q").is_err());
        assert!(!b.health().is_healthy());
        assert_eq!(b.health().readmissions(), 0);
    }

    #[test]
    fn probe_records_backend_load() {
        let addr = fake_backend(r#"{"requests":7,"failures":0}"#, 1);
        let b = Backend::new(0, &addr, &cfg());
        let json = b.probe().unwrap();
        assert_eq!(json.get("requests").and_then(Json::as_f64), Some(7.0));
        assert_eq!(b.health().observed_load(), 7);
        assert_eq!(b.health().probes(), 1);
    }
}
