//! Entity retrieval: the four algorithms compared in the paper's
//! evaluation (§4.1–4.2), behind one trait.
//!
//! Given an entity mention, a retriever returns **every address** of that
//! entity across the forest — the step whose cost the paper attacks.
//! All four implementations are address-set-equivalent (asserted by
//! `rust/tests/baselines_agree.rs`); they differ only in how much of the
//! forest they touch:
//!
//! * [`naive::NaiveTRag`] — BFS of every tree (the Tree-RAG baseline).
//! * [`bloom_rag::BloomTRag`] — per-node subtree Blooms prune descents.
//! * [`bloom2_rag::Bloom2TRag`] — additionally skips Bloom checks just
//!   above the leaf level.
//! * [`cuckoo_rag::CuckooTRag`] — the paper's system: one filter lookup
//!   returns the precomputed block list of addresses.

pub mod bloom2_rag;
pub mod bloom_rag;
pub mod context;
pub mod cuckoo_rag;
pub mod naive;

use crate::forest::EntityAddress;

/// A Tree-RAG entity retriever.
pub trait Retriever {
    /// Algorithm name as printed in result tables (paper's abbreviations).
    fn name(&self) -> &'static str;

    /// All addresses of `entity` (normalized name) in the forest.
    /// `&mut` because the Cuckoo retriever updates temperatures.
    fn find(&mut self, entity: &str) -> Vec<EntityAddress>;

    /// Allocation-free variant for hot loops: append all addresses of
    /// `entity` to `out` (which the caller clears and reuses). Default
    /// delegates to [`find`]; implementations override to avoid the
    /// per-call `Vec`.
    fn find_into(&mut self, entity: &str, out: &mut Vec<EntityAddress>) {
        out.extend(self.find(entity));
    }

    /// End-of-round maintenance (the Cuckoo retriever re-sorts buckets
    /// by temperature here; others no-op).
    fn maintain(&mut self) {}

    /// Knowledge update: the forest grew by `new_trees` (appended tree
    /// indices; existing trees are immutable). Implementations refresh
    /// their index — the Cuckoo retriever does this *incrementally*
    /// (insert/extend only the new addresses, paper §5's "ongoing data
    /// update"), while Bloom baselines must rebuild their per-node
    /// annotations.
    fn reindex(&mut self, forest: std::sync::Arc<crate::forest::Forest>, new_trees: &[u32]);

    /// Approximate heap bytes of the retriever's index structures
    /// (0 for index-free retrievers).
    fn index_bytes(&self) -> usize {
        0
    }
}

/// Convenience: retrieve several entities and concatenate address lists
/// (the multi-entity-query workload of Table 2).
pub fn find_all(
    r: &mut dyn Retriever,
    entities: &[String],
) -> Vec<(String, Vec<EntityAddress>)> {
    entities
        .iter()
        .map(|e| (e.clone(), r.find(e)))
        .collect()
}
