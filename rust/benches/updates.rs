//! Dynamic-update bench (paper §5: CF "is suitable for ongoing data
//! update"): cost of ingesting one new document (tree) into an existing
//! index, per algorithm. The Cuckoo retriever reindexes *incrementally*
//! (insert only the new addresses); the Bloom baselines must rebuild
//! their per-node annotations; Naive is index-free.
//!
//! Run: `cargo bench --bench updates`. Writes `results/updates.csv`.

use std::sync::Arc;

use cft_rag::bench::experiments::experiment_forest;
use cft_rag::bench::harness::{fmt_secs, print_table};
use cft_rag::forest::builder::build_trees;
use cft_rag::rag::config::{Algorithm, RagConfig};
use cft_rag::rag::pipeline::make_retriever;
use cft_rag::util::cli::{spec, Args};
use cft_rag::util::csv::CsvTable;

fn main() {
    let args = Args::from_env(vec![
        spec("trees", "comma-separated base forest sizes", Some("50,300,600"), false),
        spec("repeats", "timed repeats", Some("10"), false),
        spec("out", "CSV output path", Some("results/updates.csv"), false),
        spec("bench", "ignored (cargo bench passes it)", None, true),
    ])
    .unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    if args.wants_help() {
        println!("{}", args.usage());
        return;
    }
    let repeats: usize = args.num_or("repeats", 10);
    let tree_counts: Vec<usize> = args.list_or("trees", &[50, 300, 600]);

    let mut csv = CsvTable::new(&["base_trees", "algorithm", "update_time_s"]);
    let mut rows = Vec::new();
    for &trees in &tree_counts {
        let base = experiment_forest(trees, 42);
        // the incoming document: one new hospital with a dozen relations
        let new_relations: Vec<(String, String)> = (0..12)
            .map(|i| (format!("new unit {i}"), "updated hospital".to_string()))
            .chain([("cardiology".to_string(), "updated hospital".to_string())])
            .collect();

        for alg in Algorithm::ALL {
            let cfg = RagConfig { algorithm: alg, ..RagConfig::default() };
            // pre-grow the forest once (identical for all repeats)
            let mut grown = (*base).clone();
            let new_trees = build_trees(&mut grown, &new_relations);
            let grown = Arc::new(grown);

            // a fresh retriever per sample: reindex must apply exactly once
            let mut samples = Vec::with_capacity(repeats);
            for _ in 0..=repeats {
                let mut retriever = make_retriever(base.clone(), &cfg);
                let timer = cft_rag::util::stats::Timer::start();
                retriever.reindex(grown.clone(), &new_trees);
                samples.push(timer.secs());
            }
            samples.remove(0); // warmup
            let t = cft_rag::util::stats::Summary::of(&samples).p50;
            rows.push(vec![
                trees.to_string(),
                alg.label().to_string(),
                fmt_secs(t),
            ]);
            csv.push(&[trees.to_string(), alg.label().to_string(), format!("{t}")]);
        }
    }
    print_table(
        "Dynamic updates — reindex cost for one new document",
        &["base_trees", "algorithm", "update_time_s"],
        &rows,
    );
    let out = args.str_or("out", "results/updates.csv");
    csv.write_to(&out).expect("write csv");
    println!("\nwrote {out}");
}
