//! Named-entity recognition — the SpaCy stand-in (paper §2.1).
//!
//! Two recognizers:
//!
//! * [`GazetteerNer`] — used on the *query path* (Figure 1: "key entities
//!   are identified from entity trees"): matches longest n-grams of the
//!   query against the forest's known entity names. Deterministic and
//!   exact, which is what the retrieval benchmarks need.
//! * [`heuristic_entities`] — used on the *pre-processing path* for raw
//!   text: capitalized-span detection with stopword trimming, the
//!   classic rule-based NE heuristic.

use std::collections::HashMap;

use crate::text::normalize::{is_capitalized, normalize};
use crate::text::stopwords::is_stopword;

/// Longest-match gazetteer recognizer over known entity names.
#[derive(Clone, Debug, Default)]
pub struct GazetteerNer {
    /// normalized name -> original name
    names: HashMap<String, String>,
    /// longest gazetteer entry, in words
    max_words: usize,
}

impl GazetteerNer {
    /// Build from an iterator of entity names.
    pub fn new<'a>(names: impl IntoIterator<Item = &'a str>) -> Self {
        let mut map = HashMap::new();
        let mut max_words = 1;
        for name in names {
            let norm = normalize(name);
            if norm.is_empty() {
                continue;
            }
            max_words = max_words.max(norm.split_whitespace().count());
            map.insert(norm, name.to_string());
        }
        GazetteerNer { names: map, max_words }
    }

    /// Number of gazetteer entries.
    pub fn len(&self) -> usize {
        self.names.len()
    }

    /// True if the gazetteer is empty.
    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// Recognize entities in `text`, longest match first, no overlaps.
    /// Returns the gazetteer's original names in query order.
    pub fn recognize(&self, text: &str) -> Vec<String> {
        let norm = normalize(text);
        let words: Vec<&str> = norm.split_whitespace().collect();
        let mut out = Vec::new();
        let mut i = 0;
        while i < words.len() {
            let mut matched = 0;
            // longest window first
            let max_w = self.max_words.min(words.len() - i);
            for w in (1..=max_w).rev() {
                let cand = words[i..i + w].join(" ");
                if let Some(orig) = self.names.get(&cand) {
                    out.push(orig.clone());
                    matched = w;
                    break;
                }
            }
            i += if matched > 0 { matched } else { 1 };
        }
        out
    }
}

/// Heuristic NER for raw text: maximal runs of capitalized words (allowing
/// inner stopwords like "of"), trimmed of leading/trailing stopwords.
/// Mirrors what a small statistical NER would produce on clean text.
pub fn heuristic_entities(raw: &str) -> Vec<String> {
    let mut out = Vec::new();
    let words: Vec<&str> = raw.split_whitespace().collect();
    let mut run: Vec<&str> = Vec::new();
    let mut first_word = true;

    let flush = |run: &mut Vec<&str>, out: &mut Vec<String>| {
        // trim stopwords at both ends
        while run
            .first()
            .is_some_and(|w| is_stopword(&w.to_lowercase()))
        {
            run.remove(0);
        }
        while run
            .last()
            .is_some_and(|w| is_stopword(&w.to_lowercase()))
        {
            run.pop();
        }
        if !run.is_empty() {
            let name = normalize(&run.join(" "));
            if !name.is_empty() {
                out.push(name);
            }
        }
        run.clear();
    };

    for w in words {
        let clean = w.trim_matches(|c: char| !c.is_alphanumeric());
        if clean.is_empty() {
            flush(&mut run, &mut out);
            first_word = w.ends_with(['.', '!', '?']);
            continue;
        }
        let lower = clean.to_lowercase();
        let cap = is_capitalized(clean);
        // Sentence-initial capitals are ambiguous; only extend an existing
        // run with them, never start one.
        if cap && (!first_word || !run.is_empty()) {
            run.push(clean);
        } else if !run.is_empty() && is_stopword(&lower) {
            run.push(clean); // allow "Ministry of Health"
        } else {
            flush(&mut run, &mut out);
        }
        if w.ends_with(['.', '!', '?']) {
            flush(&mut run, &mut out);
            first_word = true;
        } else {
            first_word = false;
        }
    }
    flush(&mut run, &mut out);
    out.dedup();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gazetteer_matches_longest() {
        let ner = GazetteerNer::new(["cardiology", "cardiology icu", "surgery"]);
        let found = ner.recognize("tell me about the Cardiology ICU and surgery");
        assert_eq!(found, vec!["cardiology icu", "surgery"]);
    }

    #[test]
    fn gazetteer_no_overlap() {
        let ner = GazetteerNer::new(["alpha beta", "beta gamma"]);
        let found = ner.recognize("alpha beta gamma");
        // greedy left-to-right: "alpha beta" consumes beta
        assert_eq!(found, vec!["alpha beta"]);
    }

    #[test]
    fn gazetteer_normalization_invariant() {
        let ner = GazetteerNer::new(["Mercy Hospital"]);
        assert_eq!(ner.recognize("about MERCY hospital?"), vec!["Mercy Hospital"]);
    }

    #[test]
    fn gazetteer_empty_query() {
        let ner = GazetteerNer::new(["x"]);
        assert!(ner.recognize("").is_empty());
    }

    #[test]
    fn heuristic_finds_capitalized_spans() {
        let ents = heuristic_entities(
            "The department was renamed Mercy General Hospital in 1954. \
             Doctors at the Cardiology Center treated patients.",
        );
        assert!(ents.contains(&"mercy general hospital".to_string()), "{ents:?}");
        assert!(ents.contains(&"cardiology center".to_string()), "{ents:?}");
    }

    #[test]
    fn heuristic_allows_inner_stopwords() {
        let ents = heuristic_entities("She joined the Ministry of Health last year.");
        assert!(ents.contains(&"ministry of health".to_string()), "{ents:?}");
    }

    #[test]
    fn heuristic_skips_sentence_initial_cap() {
        let ents = heuristic_entities("Yesterday the clinic opened. Surgeons arrived.");
        assert!(ents.is_empty(), "{ents:?}");
    }
}
