//! Text normalization and sentence splitting for the pre-processing
//! pipeline (paper §2: raw text -> entities -> relations).

/// Lowercase, collapse whitespace, strip non-alphanumeric edge punctuation.
pub fn normalize(text: &str) -> String {
    let mut out = String::with_capacity(text.len());
    let mut last_space = true;
    for c in text.chars() {
        let c = if c.is_alphanumeric() || c == '\'' || c == '-' {
            c.to_ascii_lowercase()
        } else {
            ' '
        };
        if c == ' ' {
            if !last_space {
                out.push(' ');
                last_space = true;
            }
        } else {
            out.push(c);
            last_space = false;
        }
    }
    out.trim().to_string()
}

/// Split text into sentences on `.`, `!`, `?`, `;` and newlines, keeping
/// non-empty trimmed segments. Abbreviation-naive by design: the synthetic
/// corpora avoid ambiguous periods.
pub fn sentences(text: &str) -> Vec<String> {
    text.split(|c| matches!(c, '.' | '!' | '?' | ';' | '\n'))
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect()
}

/// Split a normalized string into words.
pub fn words(text: &str) -> Vec<&str> {
    text.split_whitespace().collect()
}

/// Title-case detector: does this raw (un-normalized) word start uppercase?
pub fn is_capitalized(word: &str) -> bool {
    word.chars().next().is_some_and(|c| c.is_uppercase())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_lowercases_and_collapses() {
        assert_eq!(
            normalize("  The  Cardiology   Department! "),
            "the cardiology department"
        );
    }

    #[test]
    fn normalize_keeps_hyphens_apostrophes() {
        assert_eq!(normalize("St-Mary's Ward"), "st-mary's ward");
    }

    #[test]
    fn sentences_split_and_trim() {
        let s = sentences("Alpha beta. Gamma!  Delta?\nEpsilon; ");
        assert_eq!(s, vec!["Alpha beta", "Gamma", "Delta", "Epsilon"]);
    }

    #[test]
    fn sentences_empty_input() {
        assert!(sentences("  . ! ").is_empty());
    }

    #[test]
    fn words_splits() {
        assert_eq!(words("a b  c"), vec!["a", "b", "c"]);
    }

    #[test]
    fn capitalization_detector() {
        assert!(is_capitalized("Hospital"));
        assert!(!is_capitalized("hospital"));
        assert!(!is_capitalized(""));
    }
}
