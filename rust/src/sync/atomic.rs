//! Model-checkable atomics (`--features modelcheck`).
//!
//! Thin wrappers over `std::sync::atomic` that insert a scheduler
//! yield before every shared-access operation, making each load/store
//! an interleaving point the model explores (that is how the checker's
//! own lost-update canary finds its bug). `get_mut`/`into_inner` need
//! `&mut self`/ownership — no concurrent access is possible — so they
//! are not scheduling points, matching std's semantics exactly.

pub use std::sync::atomic::Ordering;

use crate::modelcheck::managed;

#[inline]
fn sync_op() {
    if let Some((sh, vtid)) = managed() {
        sh.yield_point(vtid);
    }
}

macro_rules! int_atomic {
    ($(#[$doc:meta])* $Name:ident, $T:ty) => {
        $(#[$doc])*
        #[derive(Debug, Default)]
        pub struct $Name(std::sync::atomic::$Name);

        impl $Name {
            /// See the `std::sync::atomic` counterpart.
            pub const fn new(v: $T) -> Self {
                $Name(std::sync::atomic::$Name::new(v))
            }

            /// Scheduling point + atomic load.
            pub fn load(&self, order: Ordering) -> $T {
                sync_op();
                self.0.load(order)
            }

            /// Scheduling point + atomic store.
            pub fn store(&self, val: $T, order: Ordering) {
                sync_op();
                self.0.store(val, order);
            }

            /// Scheduling point + atomic swap.
            pub fn swap(&self, val: $T, order: Ordering) -> $T {
                sync_op();
                self.0.swap(val, order)
            }

            /// Scheduling point + atomic add.
            pub fn fetch_add(&self, val: $T, order: Ordering) -> $T {
                sync_op();
                self.0.fetch_add(val, order)
            }

            /// Scheduling point + atomic subtract.
            pub fn fetch_sub(&self, val: $T, order: Ordering) -> $T {
                sync_op();
                self.0.fetch_sub(val, order)
            }

            /// Scheduling point + atomic read-modify-write. The whole
            /// RMW is one step (it is atomic in the real build too).
            pub fn fetch_update<F>(
                &self,
                set_order: Ordering,
                fetch_order: Ordering,
                f: F,
            ) -> Result<$T, $T>
            where
                F: FnMut($T) -> Option<$T>,
            {
                sync_op();
                self.0.fetch_update(set_order, fetch_order, f)
            }

            /// Exclusive access; not a scheduling point (see module
            /// docs).
            pub fn get_mut(&mut self) -> &mut $T {
                self.0.get_mut()
            }

            /// Consume and return the value; not a scheduling point.
            pub fn into_inner(self) -> $T {
                self.0.into_inner()
            }
        }
    };
}

int_atomic!(
    /// Model-checkable [`std::sync::atomic::AtomicU32`].
    AtomicU32,
    u32
);
int_atomic!(
    /// Model-checkable [`std::sync::atomic::AtomicU64`].
    AtomicU64,
    u64
);
int_atomic!(
    /// Model-checkable [`std::sync::atomic::AtomicUsize`].
    AtomicUsize,
    usize
);

/// Model-checkable [`std::sync::atomic::AtomicBool`].
#[derive(Debug, Default)]
pub struct AtomicBool(std::sync::atomic::AtomicBool);

impl AtomicBool {
    /// See [`std::sync::atomic::AtomicBool::new`].
    pub const fn new(v: bool) -> Self {
        AtomicBool(std::sync::atomic::AtomicBool::new(v))
    }

    /// Scheduling point + atomic load.
    pub fn load(&self, order: Ordering) -> bool {
        sync_op();
        self.0.load(order)
    }

    /// Scheduling point + atomic store.
    pub fn store(&self, val: bool, order: Ordering) {
        sync_op();
        self.0.store(val, order);
    }

    /// Scheduling point + atomic swap.
    pub fn swap(&self, val: bool, order: Ordering) -> bool {
        sync_op();
        self.0.swap(val, order)
    }

    /// Exclusive access; not a scheduling point.
    pub fn get_mut(&mut self) -> &mut bool {
        self.0.get_mut()
    }

    /// Consume and return the value; not a scheduling point.
    pub fn into_inner(self) -> bool {
        self.0.into_inner()
    }
}
