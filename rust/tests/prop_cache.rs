//! Cache-consistency property: a router with the reply cache ON is
//! **observationally identical** to one without it, under arbitrary
//! interleavings of queries, dynamic writes (`\x01insert`/`\x01delete`
//! through the cached router), and membership epoch rolls (real
//! `\x01join`/`\x01drain` of a spare backend).
//!
//! Both routers front the SAME live partitioned fleet, so the only
//! thing that can diverge is the cache itself: any stale entry — one
//! surviving a write's point invalidation, an epoch roll's flush, or a
//! fill race — shows up as a byte-level reply mismatch. Timing fields
//! (`retrieval_us`/`total_ms`) are stripped before comparison; every
//! other byte must match. On failure the harness shrinks to a minimal
//! violating op sequence and prints the seed
//! (`CFT_PROPTEST_SEED=<seed>` replays it).

use std::net::TcpListener;
use std::sync::Arc;

use cft_rag::coordinator::tcp::{serve_listener, ServeHandle};
use cft_rag::coordinator::{Coordinator, CoordinatorConfig};
use cft_rag::data::corpus::corpus_from_texts;
use cft_rag::data::hospital::{HospitalConfig, HospitalDataset};
use cft_rag::forest::EntityAddress;
use cft_rag::rag::config::{KeyPartition, RagConfig, RouterConfig};
use cft_rag::router::Router;
use cft_rag::runtime::engine::{Engine, NativeEngine};
use cft_rag::util::json::Json;
use cft_rag::util::proptest::{forall, shrink_vec, Config};
use cft_rag::util::rng::Rng;
use std::time::Duration;

/// One in-process backend: a coordinator behind a real TCP listener.
struct TestBackend {
    coordinator: Arc<Coordinator>,
    handle: Option<ServeHandle>,
    addr: String,
}

impl TestBackend {
    fn start_on(
        ds: &HospitalDataset,
        listener: TcpListener,
        cfg: RagConfig,
    ) -> TestBackend {
        let forest = Arc::new(ds.build_forest());
        let engine: Arc<dyn Engine> = Arc::new(NativeEngine::new());
        let coordinator = Arc::new(
            Coordinator::start(
                forest,
                corpus_from_texts(&ds.documents()),
                engine,
                cfg,
                CoordinatorConfig { workers: 2, ..Default::default() },
            )
            .expect("backend coordinator"),
        );
        let handle = serve_listener(coordinator.clone(), listener)
            .expect("backend listener");
        let addr = handle.addr().to_string();
        TestBackend { coordinator, handle: Some(handle), addr }
    }

    fn kill(&mut self) {
        if let Some(h) = self.handle.take() {
            h.shutdown();
        }
        self.coordinator.stop();
    }
}

impl Drop for TestBackend {
    fn drop(&mut self) {
        self.kill();
    }
}

/// One step of a generated history.
#[derive(Clone, Debug)]
enum Op {
    /// Ask about pool entity `i` through BOTH routers; replies must be
    /// byte-identical (modulo timing fields).
    Query(usize),
    /// Re-insert pool entity `i`'s first forest occurrence through the
    /// cached router (idempotent when present — the ack still
    /// invalidates, which is part of what's under test).
    Insert(usize),
    /// Delete pool entity `i` through the cached router.
    Delete(usize),
    /// Roll the membership epoch: join a fresh spare backend, or drain
    /// the one joined by the previous roll.
    EpochRoll,
}

/// Deterministic, prober-free router config.
fn base_cfg() -> RouterConfig {
    RouterConfig {
        replication_factor: 2,
        probe_interval: Duration::ZERO,
        connect_timeout: Duration::from_secs(2),
        request_timeout: Duration::from_secs(10),
        ..RouterConfig::default()
    }
}

/// The live fleet both routers front, plus the cycling spare.
struct Fleet {
    ds: HospitalDataset,
    names: Vec<String>,
    /// Current member addresses (incumbents, plus the spare when joined).
    members: Vec<String>,
    _incumbents: Vec<TestBackend>,
    spare: Option<TestBackend>,
    /// Cache ON — the router under test; join/drain run through it.
    cached: Arc<Router>,
    /// Cache OFF — the oracle; rebuilt after every membership change.
    uncached: Router,
}

impl Fleet {
    fn start() -> Fleet {
        let ds = HospitalDataset::generate(HospitalConfig {
            trees: 4,
            ..HospitalConfig::default()
        });
        let names: Vec<String> = ds
            .build_forest()
            .interner()
            .iter()
            .map(|(_, n)| n.to_string())
            .collect();
        let listeners: Vec<TcpListener> = (0..3)
            .map(|_| TcpListener::bind("127.0.0.1:0").expect("bind"))
            .collect();
        let members: Vec<String> = listeners
            .iter()
            .map(|l| l.local_addr().unwrap().to_string())
            .collect();
        let incumbents: Vec<TestBackend> = listeners
            .into_iter()
            .enumerate()
            .map(|(i, listener)| {
                TestBackend::start_on(
                    &ds,
                    listener,
                    RagConfig {
                        replication_factor: 2,
                        key_partition: Some(
                            KeyPartition::new(members.clone(), i, 2)
                                .expect("partition"),
                        ),
                        ..RagConfig::default()
                    },
                )
            })
            .collect();
        let cached = Arc::new(
            Router::connect(
                names.iter().map(String::as_str),
                &RouterConfig {
                    backends: members.clone(),
                    cache_capacity_bytes: 256 * 1024,
                    ..base_cfg()
                },
            )
            .expect("cached router"),
        );
        let uncached = Self::oracle(&names, &members);
        Fleet {
            ds,
            names,
            members,
            _incumbents: incumbents,
            spare: None,
            cached,
            uncached,
        }
    }

    /// A fresh cache-less router over the current membership. Ownership
    /// is rendezvous-hashed over the address *set*, so a rebuilt ring
    /// routes identically to the evolved one the cached router holds.
    fn oracle(names: &[String], members: &[String]) -> Router {
        Router::connect(
            names.iter().map(String::as_str),
            &RouterConfig {
                backends: members.to_vec(),
                cache_capacity_bytes: 0,
                ..base_cfg()
            },
        )
        .expect("oracle router")
    }

    /// Join a fresh spare, or drain the currently joined one.
    fn roll_epoch(&mut self) {
        if let Some(mut spare) = self.spare.take() {
            let reply = self.cached.drain(&spare.addr);
            assert_eq!(
                reply.get("ok"),
                Some(&Json::Bool(true)),
                "harness: drain failed: {reply}"
            );
            self.members.retain(|a| a != &spare.addr);
            spare.kill();
        } else {
            let listener =
                TcpListener::bind("127.0.0.1:0").expect("bind spare");
            let addr = listener.local_addr().unwrap().to_string();
            let mut new_list = self.members.clone();
            new_list.push(addr.clone());
            let spare = TestBackend::start_on(
                &self.ds,
                listener,
                RagConfig {
                    replication_factor: 2,
                    key_partition: Some(
                        KeyPartition::joining(
                            new_list.clone(),
                            new_list.len() - 1,
                            2,
                        )
                        .expect("joining partition"),
                    ),
                    ..RagConfig::default()
                },
            );
            let reply = self.cached.join(&addr);
            assert_eq!(
                reply.get("ok"),
                Some(&Json::Bool(true)),
                "harness: join failed: {reply}"
            );
            self.members = new_list;
            self.spare = Some(spare);
        }
        self.uncached = Self::oracle(&self.names, &self.members);
    }
}

/// Canonical reply text: timing fields vary run to run and carry no
/// retrieval semantics; everything else must match to the byte.
fn stripped(reply: &Json) -> String {
    fn strip(j: &Json) -> Json {
        match j {
            Json::Obj(m) => Json::Obj(
                m.iter()
                    .filter(|(k, _)| {
                        k.as_str() != "retrieval_us"
                            && k.as_str() != "total_ms"
                    })
                    .map(|(k, v)| (k.clone(), strip(v)))
                    .collect(),
            ),
            Json::Arr(a) => Json::Arr(a.iter().map(strip).collect()),
            other => other.clone(),
        }
    }
    strip(reply).to_string()
}

#[test]
fn cached_router_is_byte_identical_to_uncached_under_any_interleaving() {
    let fleet = std::cell::RefCell::new(Fleet::start());

    // pool: entities with at least one forest occurrence, so Insert
    // ops have a real address to (re-)plant
    let forest = fleet.borrow().ds.build_forest();
    let pool: Vec<(String, EntityAddress)> = fleet
        .borrow()
        .names
        .iter()
        .filter_map(|n| {
            forest.entity_id(n).and_then(|id| {
                forest
                    .scan_addresses(id)
                    .first()
                    .map(|a| (n.clone(), *a))
            })
        })
        .take(8)
        .collect();
    assert!(pool.len() >= 4, "need a few occupied entities");
    let pool_len = pool.len() as u64;

    let gen = |rng: &mut Rng| -> Vec<Op> {
        let len = rng.range(2, 7);
        (0..len)
            .map(|_| match rng.below(8) {
                0 => Op::EpochRoll,
                1 | 2 => Op::Insert(rng.below(pool_len) as usize),
                3 | 4 => Op::Delete(rng.below(pool_len) as usize),
                _ => Op::Query(rng.below(pool_len) as usize),
            })
            .collect()
    };

    let prop = |ops: &Vec<Op>| -> Result<(), String> {
        let mut fleet = fleet.borrow_mut();
        let compare = |fleet: &Fleet, i: usize| -> Result<(), String> {
            let q = format!("tell me about {}", pool[i].0);
            let hot = stripped(&fleet.cached.query(&q));
            let cold = stripped(&fleet.uncached.query(&q));
            if hot == cold {
                Ok(())
            } else {
                Err(format!(
                    "stale or divergent reply for {:?}:\n  \
                     cached:   {hot}\n  uncached: {cold}",
                    pool[i].0
                ))
            }
        };
        for op in ops {
            match op {
                Op::Query(i) => compare(&fleet, *i)?,
                Op::Insert(i) => {
                    let (name, addr) = &pool[*i];
                    let reply =
                        fleet.cached.update(name, addr.tree, addr.node);
                    if reply.get("ok") != Some(&Json::Bool(true)) {
                        return Err(format!("insert NACKed: {reply}"));
                    }
                }
                Op::Delete(i) => {
                    let reply = fleet.cached.remove(&pool[*i].0);
                    if reply.get("ok") != Some(&Json::Bool(true)) {
                        return Err(format!("delete NACKed: {reply}"));
                    }
                }
                Op::EpochRoll => fleet.roll_epoch(),
            }
        }
        // final sweep: probe the whole pool, not just the sequence's
        // own queries — a stale entry planted by this history must not
        // survive to poison the next one
        for i in 0..pool.len() {
            compare(&fleet, i)?;
        }
        Ok(())
    };

    forall(
        Config { cases: 20, max_shrinks: 40, ..Config::default() },
        gen,
        prop,
        |ops| shrink_vec(ops),
    );
}
