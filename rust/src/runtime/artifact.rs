//! Artifact manifest: shapes and files produced by `python/compile/aot.py`
//! (`make artifacts`). The manifest pins the contract between the L2
//! graphs and the Rust hot path — batch size, embedding dim, shard size —
//! so a drifted artifact directory fails fast instead of mis-executing.

use std::path::{Path, PathBuf};

use crate::error::{CftError, Result};
use crate::util::json::Json;

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub embed_dim: usize,
    pub max_tokens: usize,
    pub shard_docs: usize,
    pub max_facts: usize,
    pub batch: usize,
    pub pad_id: i32,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            CftError::Artifact(format!(
                "cannot read {} (run `make artifacts`): {e}",
                path.display()
            ))
        })?;
        let doc = Json::parse(&text)
            .map_err(|e| CftError::Artifact(format!("bad manifest: {e}")))?;
        let get = |k: &str| -> Result<usize> {
            doc.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| CftError::Artifact(format!("manifest missing '{k}'")))
        };
        let m = Manifest {
            embed_dim: get("embed_dim")?,
            max_tokens: get("max_tokens")?,
            shard_docs: get("shard_docs")?,
            max_facts: get("max_facts")?,
            batch: get("batch")?,
            pad_id: get("pad_id")? as i32,
            dir,
        };
        for name in ["embed", "score", "rank"] {
            let f = m.hlo_path(name);
            if !f.exists() {
                return Err(CftError::Artifact(format!(
                    "artifact {} missing (run `make artifacts`)",
                    f.display()
                )));
            }
        }
        Ok(m)
    }

    /// Path of one artifact's HLO text.
    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }
}

/// Default artifact directory: `$CFT_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var("CFT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_dir_is_clear_error() {
        let err = Manifest::load("/nonexistent/path").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("make artifacts"), "{msg}");
    }

    #[test]
    fn loads_real_manifest_when_present() {
        // Integration-level check, but cheap: if artifacts/ exists in the
        // repo root, it must parse and agree with the Python constants.
        let dir = default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts present");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.embed_dim, 64);
        assert_eq!(m.max_tokens, 32);
        assert_eq!(m.shard_docs, 1024);
        assert_eq!(m.max_facts, 64);
        assert_eq!(m.batch, 8);
    }
}
