//! Membership-filter substrate: the paper's improved Cuckoo Filter
//! (fingerprints + temperature + block linked lists) and the Bloom-filter
//! baselines it is compared against.

pub mod blocklist;
pub mod bloom;
pub mod cuckoo;
pub mod fingerprint;
pub mod sharded;
pub mod tree_bloom;

pub use blocklist::{BlockArena, BLOCK_CAP, NIL};
pub use bloom::BloomFilter;
pub use cuckoo::{
    BucketPlan, CuckooConfig, CuckooFilter, CuckooStats, LookupHit,
    KICK_DEPTH_BUCKETS,
};
pub use fingerprint::entity_key;
pub use sharded::{FilterTelemetry, ShardedCuckooFilter};
pub use tree_bloom::BloomForest;
