//! Answer generation — the deterministic LLM stand-in (DESIGN.md
//! §Substitutions).
//!
//! The paper feeds the assembled prompt to an external LLM. Offline we
//! generate answers *from the same prompt content* in two steps:
//!
//! 1. **Neural fact ranking** (real request-path ML): the query and each
//!    context fact are embedded by the embed artifact, and the rank
//!    artifact (Pallas masked-attention kernel) produces attention
//!    weights; facts are ordered by weight.
//! 2. **Template realization**: ordered facts are rendered into answer
//!    sentences.
//!
//! Because step 2 states exactly the facts present in the retrieved
//! context, answer accuracy (judged against gold hierarchy facts)
//! measures retrieval completeness — the quantity the paper's filters
//! could affect — while the ~66% plateau emerges from context-window
//! limits, as in the paper.

use crate::error::Result;
use crate::llm::prompt::Prompt;
use crate::retrieval::context::Context;
use crate::runtime::engine::Engine;
use crate::text::tokenizer::tokenize_padded;

/// A generated answer plus ranking diagnostics.
#[derive(Clone, Debug)]
pub struct Answer {
    pub text: String,
    /// (fact sentence, attention weight), ordered by weight desc.
    pub ranked_facts: Vec<(String, f32)>,
}

/// Deterministic generator over an [`Engine`].
pub struct Generator<'a> {
    engine: &'a dyn Engine,
    cache: Option<crate::llm::cache::EmbedCache>,
}

impl<'a> Generator<'a> {
    /// Wrap an engine.
    pub fn new(engine: &'a dyn Engine) -> Self {
        Generator { engine, cache: None }
    }

    /// Wrap an engine with a shared fact-embedding cache (serving path;
    /// Zipf-repeated fact sentences skip re-embedding).
    pub fn with_cache(
        engine: &'a dyn Engine,
        cache: crate::llm::cache::EmbedCache,
    ) -> Self {
        Generator { engine, cache: Some(cache) }
    }

    /// Generate an answer for one (query, context) pair.
    ///
    /// Facts beyond the artifact's `max_facts` are ranked in chunks and
    /// merged, so large contexts degrade gracefully rather than truncate.
    pub fn generate(&self, query: &str, context: &Context, prompt: &Prompt) -> Result<Answer> {
        let shape = self.engine.shape();
        let sentences: Vec<String> =
            context.facts.iter().map(|f| f.render()).collect();
        if sentences.is_empty() {
            return Ok(Answer {
                text: format!(
                    "No hierarchy information was retrieved for: {query}."
                ),
                ranked_facts: Vec::new(),
            });
        }

        // Embed the query (batch row 0; rest padding).
        let mut qtoks = vec![0i32; shape.batch * shape.max_tokens];
        qtoks[..shape.max_tokens]
            .copy_from_slice(&tokenize_padded(query, shape.max_tokens));
        let qemb_all = self.engine.embed(&qtoks)?;
        let qrow = &qemb_all[..shape.embed_dim];

        // Rank fact sentences chunk by chunk.
        let mut ranked: Vec<(String, f32)> = Vec::with_capacity(sentences.len());
        for chunk in sentences.chunks(shape.max_facts) {
            let weights = self.rank_chunk(qrow, chunk)?;
            ranked.extend(
                chunk
                    .iter()
                    .cloned()
                    .zip(weights.iter().copied().take(chunk.len())),
            );
        }
        ranked.sort_by(|a, b| {
            b.1.partial_cmp(&a.1).unwrap().then_with(|| a.0.cmp(&b.0))
        });

        // Realize the answer: every fact is stated, hottest first (the
        // prompt demands explicit relationships; ordering mirrors the
        // attention weights an LLM would put on them).
        let mut text = format!("Answer (context: {} documents): ", prompt.documents.len());
        for (s, _) in &ranked {
            text.push_str(s);
            text.push_str(". ");
        }
        Ok(Answer { text, ranked_facts: ranked })
    }

    /// Rank up to `max_facts` sentences against a query embedding row.
    fn rank_chunk(&self, qrow: &[f32], sentences: &[String]) -> Result<Vec<f32>> {
        let shape = self.engine.shape();
        debug_assert!(sentences.len() <= shape.max_facts);

        // Embed the fact sentences (cache-aware), batching the misses.
        let d = shape.embed_dim;
        let mut fact_embs: Vec<f32> = vec![0.0; sentences.len() * d];
        let mut misses: Vec<usize> = Vec::new();
        for (i, s) in sentences.iter().enumerate() {
            match self.cache.as_ref().and_then(|c| c.get(s)) {
                Some(v) => fact_embs[i * d..(i + 1) * d].copy_from_slice(&v),
                None => misses.push(i),
            }
        }
        for chunk in misses.chunks(shape.batch) {
            let mut toks = vec![0i32; shape.batch * shape.max_tokens];
            for (bi, &i) in chunk.iter().enumerate() {
                toks[bi * shape.max_tokens..(bi + 1) * shape.max_tokens]
                    .copy_from_slice(&tokenize_padded(
                        &sentences[i],
                        shape.max_tokens,
                    ));
            }
            let emb = self.engine.embed(&toks)?;
            for (bi, &i) in chunk.iter().enumerate() {
                let row = &emb[bi * d..(bi + 1) * d];
                fact_embs[i * d..(i + 1) * d].copy_from_slice(row);
                if let Some(c) = &self.cache {
                    c.put(&sentences[i], row.to_vec());
                }
            }
        }

        // One rank call: batch row 0 carries the real request.
        let mut q = vec![0f32; shape.batch * shape.embed_dim];
        q[..shape.embed_dim].copy_from_slice(qrow);
        let mut facts = vec![0f32; shape.batch * shape.max_facts * shape.embed_dim];
        facts[..fact_embs.len()].copy_from_slice(&fact_embs);
        let mut lens = vec![0i32; shape.batch];
        lens[0] = sentences.len() as i32;
        let w = self.engine.rank(&q, &facts, &lens)?;
        Ok(w[..shape.max_facts].to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::retrieval::context::{ContextFact, Direction};
    use crate::runtime::engine::NativeEngine;

    fn ctx(pairs: &[(&str, &str)]) -> Context {
        Context {
            facts: pairs
                .iter()
                .map(|(e, r)| ContextFact {
                    entity: e.to_string(),
                    related: r.to_string(),
                    direction: Direction::Up,
                    tree: 0,
                    distance: 1,
                })
                .collect(),
        }
    }

    #[test]
    fn states_all_facts() {
        let e = NativeEngine::new();
        let g = Generator::new(&e);
        let c = ctx(&[("icu", "cardiology"), ("pharmacy", "hospital")]);
        let p = Prompt::assemble(vec![], &c, "where is the icu");
        let a = g.generate("where is the icu", &c, &p).unwrap();
        assert!(a.text.contains("icu is under cardiology"));
        assert!(a.text.contains("pharmacy is under hospital"));
        assert_eq!(a.ranked_facts.len(), 2);
    }

    #[test]
    fn relevant_fact_ranked_first() {
        let e = NativeEngine::new();
        let g = Generator::new(&e);
        let c = ctx(&[
            ("logistics warehouse", "supply division"),
            ("cardiology icu", "cardiology"),
        ]);
        let p = Prompt::assemble(vec![], &c, "tell me about the cardiology icu");
        let a = g
            .generate("tell me about the cardiology icu", &c, &p)
            .unwrap();
        assert!(
            a.ranked_facts[0].0.contains("cardiology icu"),
            "ranking: {:?}",
            a.ranked_facts
        );
    }

    #[test]
    fn empty_context_graceful() {
        let e = NativeEngine::new();
        let g = Generator::new(&e);
        let c = Context::default();
        let p = Prompt::assemble(vec![], &c, "anything");
        let a = g.generate("anything", &c, &p).unwrap();
        assert!(a.text.contains("No hierarchy information"));
    }

    #[test]
    fn many_facts_chunked() {
        let e = NativeEngine::new();
        let shape = e.shape();
        let pairs: Vec<(String, String)> = (0..shape.max_facts + 10)
            .map(|i| (format!("unit{i}"), format!("parent{i}")))
            .collect();
        let c = Context {
            facts: pairs
                .iter()
                .map(|(a, b)| ContextFact {
                    entity: a.clone(),
                    related: b.clone(),
                    direction: Direction::Up,
                    tree: 0,
                    distance: 1,
                })
                .collect(),
        };
        let g = Generator::new(&e);
        let p = Prompt::assemble(vec![], &c, "unit3");
        let a = g.generate("unit3", &c, &p).unwrap();
        assert_eq!(a.ranked_facts.len(), shape.max_facts + 10);
    }
}
