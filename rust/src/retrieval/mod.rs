//! Entity retrieval: the four algorithms compared in the paper's
//! evaluation (§4.1–4.2), behind one trait.
//!
//! Given an entity mention, a retriever returns **every address** of that
//! entity across the forest — the step whose cost the paper attacks.
//! All four implementations are address-set-equivalent (asserted by
//! `rust/tests/baselines_agree.rs`); they differ only in how much of the
//! forest they touch:
//!
//! * [`naive::NaiveTRag`] — BFS of every tree (the Tree-RAG baseline).
//! * [`bloom_rag::BloomTRag`] — per-node subtree Blooms prune descents.
//! * [`bloom2_rag::Bloom2TRag`] — additionally skips Bloom checks just
//!   above the leaf level.
//! * [`cuckoo_rag::CuckooTRag`] — the paper's system: one filter lookup
//!   returns the precomputed block list of addresses.
//!
//! Serving wraps the same algorithms in concurrency adapters: the
//! [`ConcurrentRetriever`] trait is what the coordinator's worker pool
//! shares ([`sharded_rag::ShardedCuckooTRag`] natively, the read-only
//! Bloom annotations via [`ArcRetriever`], everything else via
//! [`MutexRetriever`]). In an R-way replicated fleet the Cuckoo
//! retrievers additionally accept a
//! [`KeyPartition`](crate::rag::config::KeyPartition) at build time and
//! index only the keys whose replica set contains the backend — the
//! partitioned-backend-index half of the router's replication story
//! (see `router/` and `docs/PROTOCOL.md`). Hot entities can
//! additionally be memoized per backend by the opt-in
//! [`context_cache::ContextCache`] (`--context-cache`), under the same
//! never-stale invalidation contract as the router's reply cache.

pub mod bloom2_rag;
pub mod bloom_rag;
pub mod context;
pub mod context_cache;
pub mod cuckoo_rag;
pub mod naive;
pub mod sharded_rag;

use std::sync::{Arc, Mutex, RwLock};

use crate::forest::{EntityAddress, Forest};

/// A Tree-RAG entity retriever.
pub trait Retriever {
    /// Algorithm name as printed in result tables (paper's abbreviations).
    fn name(&self) -> &'static str;

    /// All addresses of `entity` (normalized name) in the forest.
    /// `&mut` because the Cuckoo retriever updates temperatures.
    fn find(&mut self, entity: &str) -> Vec<EntityAddress>;

    /// Allocation-free variant for hot loops: append all addresses of
    /// `entity` to `out` (which the caller clears and reuses). Default
    /// delegates to [`find`]; implementations override to avoid the
    /// per-call `Vec`.
    fn find_into(&mut self, entity: &str, out: &mut Vec<EntityAddress>) {
        out.extend(self.find(entity));
    }

    /// End-of-round maintenance (the Cuckoo retriever re-sorts buckets
    /// by temperature here; others no-op).
    fn maintain(&mut self) {}

    /// Knowledge update: the forest grew by `new_trees` (appended tree
    /// indices; existing trees are immutable). Implementations refresh
    /// their index — the Cuckoo retriever does this *incrementally*
    /// (insert/extend only the new addresses, paper §5's "ongoing data
    /// update"), while Bloom baselines must rebuild their per-node
    /// annotations.
    fn reindex(&mut self, forest: std::sync::Arc<crate::forest::Forest>, new_trees: &[u32]);

    /// Approximate heap bytes of the retriever's index structures
    /// (0 for index-free retrievers).
    fn index_bytes(&self) -> usize {
        0
    }
}

/// A retriever shared across serving threads: all operations take
/// `&self`, so worker threads retrieve **in parallel** without an
/// exclusive lock around the whole index.
///
/// [`sharded_rag::ShardedCuckooTRag`] implements this natively (per-key
/// shard read locks, atomic temperature bumps); the baselines are
/// adapted via [`MutexRetriever`], which serializes — the coordinator's
/// throughput comparison between the two is exactly the paper's
/// concurrency story.
pub trait ConcurrentRetriever: Send + Sync {
    /// Algorithm name as printed in result tables.
    fn name(&self) -> &'static str;

    /// Append all addresses of `entity` to `out` (caller clears/reuses).
    fn find_concurrent(&self, entity: &str, out: &mut Vec<EntityAddress>);

    /// End-of-round maintenance (CF temperature re-sort; others no-op).
    /// Implementations must keep `find_concurrent` flowing while this
    /// runs — the sharded retriever drains expansion migrations in
    /// bounded steps and swaps re-sorted buckets in epoch-style, never
    /// holding a shard write lock for a whole table.
    fn maintain_concurrent(&self) {}

    /// Knowledge update: the forest grew by `new_trees`.
    fn reindex_concurrent(&self, forest: Arc<Forest>, new_trees: &[u32]);

    /// Dynamic point update (the serving-path form of the paper's
    /// "ongoing data update", driven by the `\x01insert` control line):
    /// register one new occurrence of `entity`. Returns `None` when the
    /// retriever cannot apply point updates (the Bloom baselines must
    /// rebuild their whole-tree annotations), `Some(true)` when the
    /// occurrence was indexed, and `Some(false)` when nothing changed —
    /// the occurrence is already indexed (a client retrying a
    /// quorum-failed broadcast must not duplicate it) or a
    /// [`KeyPartition`](crate::rag::config::KeyPartition) excludes the
    /// key from this backend. Distinguishing a misrouted write from an
    /// idempotent retry is the caller's job (the coordinator checks its
    /// own partition before calling).
    fn insert_occurrence(
        &self,
        _entity: &str,
        _addr: EntityAddress,
    ) -> Option<bool> {
        None
    }

    /// Dynamic point removal (paper Algorithm 2, the `\x01delete`
    /// control line): drop `entity`'s index entry entirely. `None` =
    /// unsupported; `Some(existed)` otherwise — removing an absent or
    /// un-owned key is an idempotent `Some(false)`.
    fn remove_entity_concurrent(&self, _entity: &str) -> Option<bool> {
        None
    }

    /// Install a new [`KeyPartition`](crate::rag::config::KeyPartition)
    /// (or clear it with `None`) on a live retriever — the backend-side
    /// half of an elastic-membership change (`\x01repartition`, see
    /// `router/rebalance.rs`). Changes only which keys *dynamic
    /// updates* accept from now on; already-indexed entries keep
    /// serving until a drop pass reclaims them. Returns `false` when
    /// the retriever cannot repartition at all (the Bloom/naive
    /// baselines annotate whole trees).
    fn repartition_concurrent(
        &self,
        _partition: Option<crate::rag::config::KeyPartition>,
    ) -> bool {
        false
    }

    /// Bulk-drop every indexed key the **current** partition no longer
    /// owns — the incumbents' reclamation pass after a membership
    /// change moved keys away (run *after* `repartition_concurrent`, so
    /// the drop is computed against the new epoch). `None` =
    /// unsupported; `Some(n)` = keys actually removed (0 with no
    /// partition installed — a full index owns everything).
    fn drop_disowned_concurrent(&self) -> Option<usize> {
        None
    }

    /// Approximate heap bytes of the retriever's index structures.
    fn index_bytes(&self) -> usize {
        0
    }

    /// Heap bytes backing live index entries only (defaults to
    /// [`index_bytes`](ConcurrentRetriever::index_bytes)): retrievers
    /// with a free-list arena report shrinkage here when entries are
    /// dropped, even though capacity is retained for reuse.
    fn live_index_bytes(&self) -> usize {
        self.index_bytes()
    }

    /// Filter-internals snapshot for the observability plane
    /// ([`FilterTelemetry`](crate::filter::FilterTelemetry)): occupancy,
    /// probe work, kick-depth histogram, migration progress, estimated
    /// false-positive rate. `None` for retrievers without a Cuckoo
    /// Filter index (the Bloom/naive baselines).
    fn filter_telemetry(&self) -> Option<crate::filter::FilterTelemetry> {
        None
    }

    /// Lifetime `(lookups, slots_probed)` counters of the underlying
    /// filter — the tracer diffs this pair around a retrieval stage to
    /// attribute probe work to one request. `None` when there is no
    /// filter to count.
    fn probe_counters(&self) -> Option<(u64, u64)> {
        None
    }

    /// Export every live index entry as `(key, temperature, addresses)`
    /// — the image a durable snapshot (`persist/`) captures. `None` for
    /// retrievers without an exportable dynamic index (the Bloom/naive
    /// baselines rebuild from the forest instead).
    fn export_index(&self) -> Option<Vec<(u64, u32, Vec<EntityAddress>)>> {
        None
    }

    /// Replace the whole index with `entries` (a verified snapshot).
    /// The snapshot is **authoritative**: the forest-built index is
    /// cleared first, so entities deleted before the snapshot was cut
    /// stay deleted. Deliberately bypasses partition ownership checks —
    /// the snapshot was cut under the recorded partition, which the
    /// caller reinstalls alongside. `None` = unsupported; `Some(n)` =
    /// entries restored.
    fn restore_index(
        &self,
        _entries: &[(u64, u32, Vec<EntityAddress>)],
    ) -> Option<usize> {
        None
    }
}

/// Adapts any [`Retriever`] to [`ConcurrentRetriever`] by serializing
/// every call through a mutex — correctness fallback for the index-free
/// and Bloom baselines (and the unsharded-coordinator comparison arm in
/// `benches/concurrent.rs`). Throughput does not scale with threads.
pub struct MutexRetriever {
    name: &'static str,
    inner: Mutex<Box<dyn Retriever + Send>>,
}

impl MutexRetriever {
    /// Wrap a boxed retriever.
    pub fn new(retriever: Box<dyn Retriever + Send>) -> Self {
        MutexRetriever { name: retriever.name(), inner: Mutex::new(retriever) }
    }
}

impl ConcurrentRetriever for MutexRetriever {
    fn name(&self) -> &'static str {
        self.name
    }

    fn find_concurrent(&self, entity: &str, out: &mut Vec<EntityAddress>) {
        self.inner.lock().unwrap().find_into(entity, out);
    }

    fn maintain_concurrent(&self) {
        self.inner.lock().unwrap().maintain();
    }

    fn reindex_concurrent(&self, forest: Arc<Forest>, new_trees: &[u32]) {
        self.inner.lock().unwrap().reindex(forest, new_trees);
    }

    fn index_bytes(&self) -> usize {
        self.inner.lock().unwrap().index_bytes()
    }
}

/// The read path of a retriever whose index is **immutable after
/// build** — the Bloom baselines' per-node annotations are written once
/// and only ever read, so sharing them across serving threads needs no
/// lock at all. `rebuild` produces a replacement index for knowledge
/// updates (the whole-annotation rebuild cost the CF design avoids).
pub trait SharedRetriever: Send + Sync {
    /// Algorithm name as printed in result tables.
    fn name(&self) -> &'static str;

    /// Append all addresses of `entity` to `out` through `&self`.
    fn find_shared(&self, entity: &str, out: &mut Vec<EntityAddress>);

    /// Build a replacement index over the grown forest.
    fn rebuild(&self, forest: Arc<Forest>) -> Self
    where
        Self: Sized;

    /// Approximate heap bytes of the index structures.
    fn index_bytes(&self) -> usize;
}

/// Adapts a [`SharedRetriever`] to [`ConcurrentRetriever`] by sharing
/// the immutable index as an `Arc`: readers clone the `Arc` under a
/// momentary read lock and then search with **no lock held**, so — in
/// contrast to [`MutexRetriever`] — throughput scales with reader
/// threads (the ROADMAP's "Concurrent Bloom baselines" item, measured
/// by `benches/concurrent.rs`). Reindexing builds the new annotations
/// off to the side and swaps the `Arc`; in-flight readers finish on the
/// generation they started with.
pub struct ArcRetriever<R: SharedRetriever> {
    inner: RwLock<Arc<R>>,
}

impl<R: SharedRetriever> ArcRetriever<R> {
    /// Share a built index.
    pub fn new(retriever: R) -> Self {
        ArcRetriever { inner: RwLock::new(Arc::new(retriever)) }
    }

    /// The current index generation (momentary read lock).
    pub fn current(&self) -> Arc<R> {
        self.inner.read().unwrap().clone()
    }
}

impl<R: SharedRetriever> ConcurrentRetriever for ArcRetriever<R> {
    fn name(&self) -> &'static str {
        self.current().name()
    }

    fn find_concurrent(&self, entity: &str, out: &mut Vec<EntityAddress>) {
        // lock held only for the Arc clone; the search itself is free
        self.current().find_shared(entity, out);
    }

    fn reindex_concurrent(&self, forest: Arc<Forest>, _new_trees: &[u32]) {
        // build outside any lock, swap under a short write lock
        let rebuilt = Arc::new(self.current().rebuild(forest));
        *self.inner.write().unwrap() = rebuilt;
    }

    fn index_bytes(&self) -> usize {
        self.current().index_bytes()
    }
}

/// Convenience: retrieve several entities and concatenate address lists
/// (the multi-entity-query workload of Table 2).
pub fn find_all(
    r: &mut dyn Retriever,
    entities: &[String],
) -> Vec<(String, Vec<EntityAddress>)> {
    entities
        .iter()
        .map(|e| (e.clone(), r.find(e)))
        .collect()
}
