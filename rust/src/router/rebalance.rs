//! Elastic ring membership: warm-up rebalancing on backend join/drain
//! (the ROADMAP's "Rebalancing on join" item; ops procedures in
//! `docs/OPERATIONS.md`, wire format in `docs/PROTOCOL.md`).
//!
//! Ring membership used to be frozen at fleet start: adding a backend
//! address shifts *every* key's rendezvous replica set, so a joiner
//! would own keys it has never indexed and incumbents would hoard keys
//! they no longer own. This module makes membership dynamic while
//! keeping the serving invariant — **every key's serving set is fully
//! indexed at every instant** — through a four-step protocol:
//!
//! 1. **Plan + dual-write window.** The next epoch's ring is computed
//!    over the new address list and published as *pending*: queries
//!    keep routing on the current ring, but dynamic writes
//!    (`\x01insert`/`\x01delete`) are additionally applied to the
//!    incoming epoch's replica set, so no write can land "between"
//!    epochs and be lost.
//! 2. **Warm-up handoff.** For every key the change moves, a current
//!    replica dumps its indexed address list (`\x01dump`) and the
//!    router replays it to the new owner as `\x01insert` lines —
//!    batched per key (one dump returns the whole list) and
//!    retry-idempotent (a replayed insert acks `applied:false` instead
//!    of duplicating). On `join`, the mover is always the joiner; on
//!    `drain`, the leaving backend's keys go to their next-ranked
//!    owners (the drainee itself is the preferred dump source — it
//!    still holds every key it serves).
//! 3. **Epoch roll + admission.** Every member is `\x01repartition`ed
//!    to the new epoch (the [`EpochGate`] accepts both epochs during
//!    the roll), then the serving ring is swapped atomically — only
//!    now does a joiner receive reads, and only now does a drainee
//!    stop. A backend whose warm-up never completed keeps reporting a
//!    stale epoch and is refused by the health prober.
//! 4. **Drop pass.** Incumbents reclaim the keys the new epoch
//!    disowns (`\x01purge` → bulk delete), shrinking per-backend live
//!    index memory back toward the `~R/N` bound. This runs *after*
//!    admission so a reader never races a key being dropped from the
//!    replica still serving it.
//!
//! Mid-rebalance correctness is the point of the ordering: reads are
//! always served from a ring whose members hold (at least) their keys
//! — incumbents hold supersets until step 4, the joiner serves nothing
//! until step 3 — and writes are double-applied from step 1, so the
//! two coexisting partition epochs never disagree about a key.
//!
//! A `\x01join` of an address **already in the ring** takes none of
//! those steps: it is a *rejoin* — a durable backend (`persist/`) that
//! warm-restarted from its snapshot + op log at the recorded epoch and
//! only needs the writes it missed while down. See [`execute_rejoin`].

use std::io;
use std::sync::{Arc, RwLock};

use crate::coordinator::tcp::{
    DELETE_REQUEST, DUMP_REQUEST, INSERT_REQUEST, PURGE_REQUEST,
    REPARTITION_REQUEST, STATS_REQUEST,
};
use crate::filter::fingerprint::entity_key;
use crate::rag::config::RouterConfig;
use crate::reactor::client::NetDriver;
use crate::router::backend::Backend;
use crate::router::contracts;
use crate::router::health::{EpochGate, ProbeTargets};
use crate::router::metrics::RouterMetrics;
use crate::router::ring::ShardRing;
use crate::util::json::Json;
use crate::util::log;

/// One immutable generation of ring membership. The router's query
/// path clones the `Arc` and works against a consistent snapshot; a
/// rebalance builds the next generation aside and swaps it in.
#[derive(Clone)]
pub struct RingState {
    /// Rendezvous ring over the member addresses.
    pub ring: ShardRing,
    /// `backends[i]` serves `ring.name(i)`.
    pub backends: Vec<Arc<Backend>>,
    /// Fleet membership epoch of this generation.
    pub epoch: u64,
    /// The next generation while a rebalance is in flight — the
    /// dual-write window: writes also apply to this ring's replica
    /// sets. `None` in steady state.
    pub pending: Option<PendingState>,
}

impl RingState {
    /// The member addresses in ring order.
    pub fn addresses(&self) -> Vec<String> {
        (0..self.ring.len())
            .map(|i| self.ring.name(i).to_string())
            .collect()
    }
}

/// The incoming membership generation during a rebalance.
#[derive(Clone)]
pub struct PendingState {
    /// The next epoch's ring.
    pub ring: ShardRing,
    /// `backends[i]` serves `ring.name(i)` in the next epoch.
    pub backends: Vec<Arc<Backend>>,
    /// The next epoch number.
    pub epoch: u64,
}

/// Shared, swappable ring membership: the query path reads it
/// lock-free-ish (one momentary read lock to clone an `Arc`), the
/// rebalancer swaps generations, and the health prober re-reads its
/// target list from it every round.
pub struct Membership {
    state: RwLock<Arc<RingState>>,
    gate: Arc<EpochGate>,
}

impl Membership {
    /// Initial membership at epoch 0 (fleet start).
    pub fn new(
        ring: ShardRing,
        backends: Vec<Arc<Backend>>,
        gate: Arc<EpochGate>,
    ) -> Membership {
        Membership {
            state: RwLock::new(Arc::new(RingState {
                ring,
                backends,
                epoch: 0,
                pending: None,
            })),
            gate,
        }
    }

    /// The current generation (a consistent snapshot).
    pub fn load(&self) -> Arc<RingState> {
        self.state.read().unwrap().clone()
    }

    /// The epoch gate shared with every backend's prober.
    pub fn gate(&self) -> Arc<EpochGate> {
        self.gate.clone()
    }

    /// The serving epoch.
    pub fn epoch(&self) -> u64 {
        self.load().epoch
    }

    /// Open the dual-write window: publish the incoming generation as
    /// pending (queries keep routing on the current ring) and let the
    /// epoch gate accept both epochs during the roll.
    fn set_pending(&self, pending: PendingState) {
        self.gate.open(pending.epoch);
        let mut state = self.state.write().unwrap();
        crate::router::contracts::check_window_open(
            &state,
            pending.epoch,
            &self.gate,
        );
        let mut next = (**state).clone();
        next.pending = Some(pending);
        *state = Arc::new(next);
    }

    /// Abort a rebalance: drop the pending generation. The gate keeps
    /// accepting the pending epoch — members already rolled forward
    /// must not start failing probes; a retried rebalance reuses the
    /// same next epoch number.
    fn clear_pending(&self) {
        let mut state = self.state.write().unwrap();
        let mut next = (**state).clone();
        next.pending = None;
        *state = Arc::new(next);
    }

    /// Commit a rebalance: swap the serving generation and retire the
    /// old epoch (stale members now fail probes).
    fn commit(&self, new_state: RingState) {
        let epoch = new_state.epoch;
        crate::router::contracts::check_commit(&self.gate, epoch, false);
        *self.state.write().unwrap() = Arc::new(new_state);
        self.gate.commit(epoch);
        crate::router::contracts::check_commit(&self.gate, epoch, true);
    }
}

impl ProbeTargets for Membership {
    /// Serving members plus — mid-rebalance — the incoming generation's
    /// extras (the joiner warms up under observation).
    fn probe_targets(&self) -> Vec<Arc<Backend>> {
        let state = self.load();
        let mut targets = state.backends.clone();
        if let Some(p) = &state.pending {
            for b in &p.backends {
                if !targets.iter().any(|t| Arc::ptr_eq(t, b)) {
                    targets.push(b.clone());
                }
            }
        }
        targets
    }
}

/// The backends that serve `key` on `ring`: its R-way replica set, or
/// the whole ring in full-index mode (`replication == 0`).
pub fn serving_set(
    ring: &ShardRing,
    replication: usize,
    key: u64,
) -> Vec<usize> {
    if replication == 0 {
        (0..ring.len()).collect()
    } else {
        ring.replicas(key, replication)
    }
}

/// [`serving_set`] as addresses — membership changes shift ring
/// *indices*, so cross-epoch comparisons (did this key's serving set
/// actually change?) must compare addresses. Property-tested in
/// `ring.rs` (a join moves only keys whose serving set changed).
pub fn serving_addrs(
    ring: &ShardRing,
    replication: usize,
    key: u64,
) -> Vec<String> {
    serving_set(ring, replication, key)
        .into_iter()
        .map(|i| ring.name(i).to_string())
        .collect()
}

/// Outcome summary of a completed join/drain — the front door's reply
/// to `\x01join`/`\x01drain`, and what `cft-rag route --admit/--drain`
/// prints.
#[derive(Clone, Debug)]
pub struct RebalanceReport {
    /// `"join"` or `"drain"`.
    pub action: &'static str,
    /// The backend that joined or drained.
    pub addr: String,
    /// The new serving epoch.
    pub epoch: u64,
    /// Entity keys streamed during the warm-up/handoff.
    pub keys_streamed: usize,
    /// `\x01insert` replays sent while streaming those keys.
    pub inserts_sent: usize,
    /// Disowned keys reclaimed by the post-admission drop pass.
    pub keys_dropped: usize,
    /// Ring size after the change.
    pub backends: usize,
}

impl RebalanceReport {
    /// The front-door JSON reply.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("action", Json::Str(self.action.to_string())),
            ("addr", Json::Str(self.addr.clone())),
            ("epoch", Json::Num(self.epoch as f64)),
            ("keys_streamed", Json::Num(self.keys_streamed as f64)),
            ("inserts_sent", Json::Num(self.inserts_sent as f64)),
            ("keys_dropped", Json::Num(self.keys_dropped as f64)),
            ("backends", Json::Num(self.backends as f64)),
        ])
    }
}

/// Everything a rebalance needs from the router (kept explicit so the
/// execution lives here while the router's fields stay private to
/// `scatter.rs`).
pub(crate) struct RebalanceCtx<'a> {
    pub membership: &'a Arc<Membership>,
    pub metrics: &'a RouterMetrics,
    pub cfg: &'a RouterConfig,
    /// The entity vocabulary the fleet indexes — the key universe the
    /// rebalance plans over (the router localizes queries with exactly
    /// these names, so nothing else is ever routed).
    pub vocab: &'a [String],
    pub replication: usize,
    /// The router's shared outbound reactor — joining backends are
    /// dialed through the same driver as the rest of the fleet.
    pub driver: &'a Arc<NetDriver>,
}

/// Join `addr` into the serving ring: warm it up over the handoff
/// transport, roll the fleet to the next epoch, admit, then run the
/// incumbents' drop pass. See the module docs for the ordering
/// argument.
pub(crate) fn execute_join(
    ctx: &RebalanceCtx,
    addr: &str,
) -> Result<RebalanceReport, String> {
    let addr = addr.trim();
    if addr.is_empty() || addr.contains([',', ' ', '\x01']) {
        return Err(format!("invalid backend address {addr:?}"));
    }
    let old = ctx.membership.load();
    if old.pending.is_some() {
        return Err("another rebalance is in flight".into());
    }
    if let Some(idx) =
        (0..old.ring.len()).find(|&i| old.ring.name(i) == addr)
    {
        // Joining an address that is already a ring member is a
        // **rejoin**: a warm-restarted backend (snapshot + op-log
        // recovery, `persist/`) that needs only the writes it missed
        // while down — no epoch roll, no dual-write window, O(delta)
        // streaming instead of O(index).
        return execute_rejoin(ctx, addr, idx, &old);
    }

    let mut new_addrs = old.addresses();
    new_addrs.push(addr.to_string());
    let new_ring = ShardRing::new(new_addrs.iter().cloned());
    let new_epoch = old.epoch + 1;
    let joiner = Arc::new(Backend::new(
        old.backends.len(),
        addr,
        ctx.cfg,
        ctx.membership.gate(),
        ctx.driver.clone(),
    ));
    // fail before disturbing anything if the joiner is not reachable
    if let Err(e) = joiner.request(STATS_REQUEST) {
        return Err(format!("joining backend {addr} is unreachable: {e}"));
    }

    let mut new_backends = old.backends.clone();
    new_backends.push(joiner.clone());

    // step 1: dual-write window opens before any key moves
    ctx.membership.set_pending(PendingState {
        ring: new_ring.clone(),
        backends: new_backends.clone(),
        epoch: new_epoch,
    });

    // step 2: stream every key the joiner will serve, sourced from a
    // current replica (healthy first), on a bounded worker pool
    let joiner_idx = new_ring.len() - 1;
    let moved: Vec<&String> = ctx
        .vocab
        .iter()
        .filter(|name| {
            serving_set(&new_ring, ctx.replication, entity_key(name))
                .contains(&joiner_idx)
        })
        .collect();
    contracts::check_movement_plan(
        ctx.vocab,
        &old.ring,
        &new_ring,
        ctx.replication,
        &moved,
    );
    let (keys_streamed, inserts_sent) = match stream_keys(&moved, &|name| {
        let old_set =
            serving_set(&old.ring, ctx.replication, entity_key(name));
        handoff(&old.backends, &old_set, None, &joiner, name).map_err(|e| {
            format!("warm-up handoff of {name:?} to {addr} failed: {e}")
        })
    }) {
        Ok(counts) => counts,
        Err(e) => {
            ctx.membership.clear_pending();
            contracts::check_abort_unchanged(&old, &ctx.membership.load());
            return Err(e);
        }
    };

    // step 3: roll every member (incumbents keep serving their
    // supersets; the joiner — last in the list — leaves warming mode),
    // then admit. A partial roll is rolled back best-effort: a member
    // left on the new partition while the ring stays on the old epoch
    // would NACK writes for the keys it no longer owns.
    let mut rolled: Vec<usize> = Vec::new();
    for (i, b) in new_backends.iter().enumerate() {
        if let Err(e) =
            repartition(b, new_epoch, ctx.replication, i, &new_addrs)
        {
            let old_addrs = old.addresses();
            for &j in &rolled {
                // only incumbents can be in `rolled` here (the joiner
                // is last), so index j is valid in the old list too
                if let Err(re) = repartition(
                    &new_backends[j],
                    old.epoch,
                    ctx.replication,
                    j,
                    &old_addrs,
                ) {
                    log::warn!(
                        "rollback of {} to epoch {} failed (it will \
                         NACK writes for its disowned keys until the \
                         join is retried): {re}",
                        new_backends[j].addr(),
                        old.epoch
                    );
                }
            }
            ctx.membership.clear_pending();
            contracts::check_abort_unchanged(&old, &ctx.membership.load());
            return Err(format!(
                "epoch roll to {new_epoch} failed on {}: {e}",
                b.addr()
            ));
        }
        rolled.push(i);
    }
    // refresh the joiner's health under the new epoch so admission does
    // not wait out a probe interval
    let _ = joiner.probe();
    ctx.metrics.ensure_backends(new_backends.len());
    // `pre_commit` is the snapshot queries have been loading since the
    // dual-write window opened (`old` covers queries from before it);
    // both route by the old ring, so both must drain before the purge
    let pre_commit = ctx.membership.load();
    ctx.membership.commit(RingState {
        ring: new_ring,
        backends: new_backends.clone(),
        epoch: new_epoch,
        pending: None,
    });
    ctx.metrics.record_join(keys_streamed as u64);
    log::info!(
        "backend {addr} admitted at epoch {new_epoch} \
         ({keys_streamed} keys / {inserts_sent} inserts warmed)"
    );

    // step 4: incumbents reclaim what the new epoch disowns — but only
    // once no in-flight query can still route by the old ring, where
    // an evicted incumbent is a key's serving replica (purging under
    // such a reader would answer it ok-with-zero-facts)
    drain_old_readers(&[&old, &pre_commit], reader_drain_wait(ctx.cfg));
    let mut keys_dropped = 0usize;
    for b in &new_backends[..new_backends.len() - 1] {
        match purge(b) {
            Ok(n) => keys_dropped += n,
            Err(e) => log::warn!(
                "post-join purge on {} failed (disowned keys linger \
                 until the next purge): {e}",
                b.addr()
            ),
        }
    }
    ctx.metrics.record_dropped_keys(keys_dropped as u64);

    Ok(RebalanceReport {
        action: "join",
        addr: addr.to_string(),
        epoch: new_epoch,
        keys_streamed,
        inserts_sent,
        keys_dropped,
        backends: new_backends.len(),
    })
}

/// Re-admit a warm-restarted ring member by streaming only the delta
/// it missed while down — the durable-backend fast path
/// (`docs/OPERATIONS.md` "Kill recovery").
///
/// The member restored its index from its `--data-dir` snapshot +
/// op log and came back reporting the partition epoch recorded there,
/// so — unlike a cold [`execute_join`] — nothing about the ring
/// changes: no new epoch, no dual-write window, no drop pass. The only
/// work is catch-up: for every key the member owns, compare its copy
/// against a peer replica's (two `\x01dump`s, no payload streaming)
/// and replay the authoritative list only where they differ. Writes
/// landing *during* the rejoin go to the member through the normal
/// write path (it is already in every serving set it belongs to), so
/// the catch-up set only shrinks.
///
/// Sole-replica keys (`R = 1`, or every peer unreachable) have no
/// authority to reconcile against; the restored copy — complete up to
/// the last acked write, by the durability contract — stands.
///
/// Fails loudly when the member is unreachable or reports an epoch the
/// [`EpochGate`] refuses (it was down across a membership change and
/// its snapshot is stale): the operator must `\x01drain` it and
/// re-`\x01join` it cold instead.
pub(crate) fn execute_rejoin(
    ctx: &RebalanceCtx,
    addr: &str,
    member_idx: usize,
    old: &Arc<RingState>,
) -> Result<RebalanceReport, String> {
    let target = &old.backends[member_idx];
    // The probe is epoch-gated: success both proves reachability and
    // validates the recorded epoch, and re-admits the member's health
    // state so the scatter path stops failing over around it.
    if let Err(e) = target.probe() {
        return Err(format!(
            "cannot rejoin {addr}: {e} (if it restarted with a stale \
             partition epoch, drain it and join it cold instead)"
        ));
    }

    let owned: Vec<&String> = ctx
        .vocab
        .iter()
        .filter(|name| {
            serving_set(&old.ring, ctx.replication, entity_key(name))
                .contains(&member_idx)
        })
        .collect();
    let (keys_streamed, inserts_sent) = stream_keys(&owned, &|name| {
        let set = serving_set(&old.ring, ctx.replication, entity_key(name));
        let peers: Vec<usize> =
            set.into_iter().filter(|&i| i != member_idx).collect();
        if peers.is_empty() {
            return Ok(0); // sole replica: the restored copy stands
        }
        reconcile_key(&old.backends, &peers, target, name).map_err(|e| {
            format!("rejoin catch-up of {name:?} on {addr} failed: {e}")
        })
    })?;

    let _ = target.probe(); // refresh load/health post-catch-up
    ctx.metrics.record_join(keys_streamed as u64);
    log::info!(
        "backend {addr} rejoined at epoch {} \
         ({keys_streamed} keys / {inserts_sent} inserts caught up \
         out of {} owned)",
        old.epoch,
        owned.len()
    );

    Ok(RebalanceReport {
        action: "rejoin",
        addr: addr.to_string(),
        epoch: old.epoch,
        keys_streamed,
        inserts_sent,
        keys_dropped: 0,
        backends: old.backends.len(),
    })
}

/// Bring `target`'s copy of one entity in line with its peer replicas:
/// dump the first peer that answers (healthy first) as the
/// authoritative list, dump the target, and only when they differ
/// clear the target's stale copy and replay the authoritative one.
/// Returns the `\x01insert` replays sent — `0` when the copies already
/// agree (the common case after a warm restart) **and** when the only
/// divergence was a missed delete (the stale copy is removed, nothing
/// streamed). Every peer failing is an error: completing "ok" while
/// the member silently keeps a divergent copy would defeat the
/// rejoin's purpose.
fn reconcile_key(
    backends: &[Arc<Backend>],
    peers: &[usize],
    target: &Backend,
    entity: &str,
) -> io::Result<usize> {
    let mut order: Vec<usize> = peers.to_vec();
    order.sort_by_key(|&i| !backends[i].health().is_healthy());
    let mut last_err: Option<io::Error> = None;
    let (source, want) = 'found: {
        for &p in &order {
            match dump_addresses(&backends[p], entity) {
                Ok(addrs) => break 'found (Some(p), addrs),
                Err(e) => last_err = Some(e),
            }
        }
        match last_err {
            Some(e) => {
                return Err(io::Error::other(format!(
                    "no peer replica of {entity:?} could be dumped: {e}"
                )))
            }
            None => (None, Vec::new()), // no peers (guarded by caller)
        }
    };

    let have = dump_addresses(target, entity)?;
    let canon = |mut v: Vec<(u32, u32)>| {
        v.sort_unstable();
        v.dedup();
        v
    };
    if canon(want.clone()) == canon(have.clone()) {
        return Ok(0); // already caught up
    }
    if !have.is_empty() {
        // stale copy (missed delete, or divergent list): clear before
        // replaying so the replay is exact, not additive
        let reply =
            target.request(&format!("{DELETE_REQUEST} {entity}"))?;
        expect_ok(reply, "delete", target.addr())?;
    }
    let sent = replay_inserts(target, entity, &want)?;
    if sent > 0 {
        // Same dump→replay race as `handoff`: a delete landing between
        // the peer dump and the replay hit the target before the
        // replayed entries existed there. Re-dump the peer — if the
        // key is gone now, undo the replay.
        if let Some(p) = source {
            if let Ok(now) = dump_addresses(&backends[p], entity) {
                if now.is_empty() {
                    let _ = target
                        .request(&format!("{DELETE_REQUEST} {entity}"));
                    return Ok(0);
                }
            }
        }
    }
    Ok(sent)
}

/// Drain `addr` out of the serving ring: hand its keys to their
/// next-ranked owners (sourced from the drainee itself while it still
/// serves), roll the survivors to the next epoch, then remove it. The
/// drained process can be stopped by the operator once this returns.
pub(crate) fn execute_drain(
    ctx: &RebalanceCtx,
    addr: &str,
) -> Result<RebalanceReport, String> {
    let addr = addr.trim();
    let old = ctx.membership.load();
    if old.pending.is_some() {
        return Err("another rebalance is in flight".into());
    }
    let Some(drain_idx) =
        (0..old.ring.len()).find(|&i| old.ring.name(i) == addr)
    else {
        return Err(format!("{addr} is not in the serving ring"));
    };
    let floor = ctx.replication.max(1);
    if old.ring.len() <= floor {
        return Err(format!(
            "cannot drain below {floor} backend(s) (replication factor)"
        ));
    }

    let new_addrs: Vec<String> = old
        .addresses()
        .into_iter()
        .enumerate()
        .filter(|(i, _)| *i != drain_idx)
        .map(|(_, a)| a)
        .collect();
    let new_ring = ShardRing::new(new_addrs.iter().cloned());
    let new_epoch = old.epoch + 1;
    let survivors: Vec<Arc<Backend>> = old
        .backends
        .iter()
        .enumerate()
        .filter(|(i, _)| *i != drain_idx)
        .map(|(_, b)| b.clone())
        .collect();

    // step 1: dual-write window (writes also land on the new owners)
    ctx.membership.set_pending(PendingState {
        ring: new_ring.clone(),
        backends: survivors.clone(),
        epoch: new_epoch,
    });

    // step 3a — before the handoff, unlike join: survivors must accept
    // `\x01insert` for their *gained* keys, so they roll to the new
    // epoch first (their indexes are untouched; reads keep flowing on
    // the old ring, which they still fully cover). A partial roll is
    // rolled back best-effort, as on join.
    let mut rolled: Vec<usize> = Vec::new();
    for (j, b) in survivors.iter().enumerate() {
        if let Err(e) =
            repartition(b, new_epoch, ctx.replication, j, &new_addrs)
        {
            let old_addrs = old.addresses();
            for &k in &rolled {
                // survivor position k maps back to its pre-drain index
                let old_index = if k < drain_idx { k } else { k + 1 };
                if let Err(re) = repartition(
                    &survivors[k],
                    old.epoch,
                    ctx.replication,
                    old_index,
                    &old_addrs,
                ) {
                    log::warn!(
                        "rollback of {} to epoch {} failed (it will \
                         NACK writes for its disowned keys until the \
                         drain is retried): {re}",
                        survivors[k].addr(),
                        old.epoch
                    );
                }
            }
            ctx.membership.clear_pending();
            contracts::check_abort_unchanged(&old, &ctx.membership.load());
            return Err(format!(
                "epoch roll to {new_epoch} failed on {}: {e}",
                b.addr()
            ));
        }
        rolled.push(j);
    }

    // step 2: hand every key the drainee serves to its newly ranked
    // owners, preferring the drainee itself as the dump source (it is
    // the one backend guaranteed to hold them — for sole-replica keys
    // it is the only one); per-key moves run on the worker pool
    let moved: Vec<&String> = ctx
        .vocab
        .iter()
        .filter(|name| {
            // minimal disruption: a key the drainee never served keeps
            // its serving set verbatim
            serving_set(&old.ring, ctx.replication, entity_key(name))
                .contains(&drain_idx)
        })
        .collect();
    contracts::check_movement_plan(
        ctx.vocab,
        &old.ring,
        &new_ring,
        ctx.replication,
        &moved,
    );
    let (keys_streamed, inserts_sent) = match stream_keys(&moved, &|name| {
        let key = entity_key(name);
        let old_set = serving_set(&old.ring, ctx.replication, key);
        let old_addrs: Vec<&str> =
            old_set.iter().map(|&i| old.ring.name(i)).collect();
        let mut sent = 0usize;
        for &g in &serving_set(&new_ring, ctx.replication, key) {
            if old_addrs.contains(&new_ring.name(g)) {
                continue; // already holds the key
            }
            sent += handoff(
                &old.backends,
                &old_set,
                Some(drain_idx),
                &survivors[g],
                name,
            )
            .map_err(|e| {
                format!(
                    "drain handoff of {name:?} to {} failed: {e}",
                    survivors[g].addr()
                )
            })?;
        }
        Ok(sent)
    }) {
        Ok(counts) => counts,
        Err(e) => {
            ctx.membership.clear_pending();
            contracts::check_abort_unchanged(&old, &ctx.membership.load());
            return Err(e);
        }
    };

    // step 3b: the drainee leaves the serving ring. Before reporting
    // success — the operator's cue to stop the process — wait for
    // queries still holding a pre-drain snapshot, which can route the
    // drainee's keys to it until they finish.
    ctx.metrics.remove_backend(drain_idx);
    let pre_commit = ctx.membership.load();
    ctx.membership.commit(RingState {
        ring: new_ring,
        backends: survivors.clone(),
        epoch: new_epoch,
        pending: None,
    });
    drain_old_readers(&[&old, &pre_commit], reader_drain_wait(ctx.cfg));
    ctx.metrics.record_drain(keys_streamed as u64);
    log::info!(
        "backend {addr} drained at epoch {new_epoch} \
         ({keys_streamed} keys / {inserts_sent} inserts handed off); \
         the process can be stopped now"
    );

    Ok(RebalanceReport {
        action: "drain",
        addr: addr.to_string(),
        epoch: new_epoch,
        keys_streamed,
        inserts_sent,
        keys_dropped: 0,
        backends: survivors.len(),
    })
}

/// Run a per-key handoff over `keys` on a bounded worker pool — each
/// key's move is independent (one dump source, one or more insert
/// targets), so the dual-write window shrinks by the fan-out factor
/// instead of scaling with the vocabulary. Stops scheduling new keys
/// at the first failure and reports it. Returns
/// `(keys_streamed, inserts_sent)` — keys whose move sent nothing
/// (`Ok(0)`: not held anywhere, e.g. dynamically deleted) don't count.
fn stream_keys(
    keys: &[&String],
    per_key: &(dyn Fn(&str) -> Result<usize, String> + Sync),
) -> Result<(usize, usize), String> {
    use std::sync::atomic::{AtomicUsize, Ordering};
    const HANDOFF_WORKERS: usize = 8;
    let next = AtomicUsize::new(0);
    let streamed = AtomicUsize::new(0);
    let inserts = AtomicUsize::new(0);
    let failure: std::sync::Mutex<Option<String>> =
        std::sync::Mutex::new(None);
    std::thread::scope(|s| {
        for _ in 0..HANDOFF_WORKERS.min(keys.len().max(1)) {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= keys.len() || failure.lock().unwrap().is_some() {
                    break;
                }
                match per_key(keys[i]) {
                    Ok(0) => {}
                    Ok(n) => {
                        streamed.fetch_add(1, Ordering::Relaxed);
                        inserts.fetch_add(n, Ordering::Relaxed);
                    }
                    Err(e) => {
                        *failure.lock().unwrap() = Some(e);
                        break;
                    }
                }
            });
        }
    });
    match failure.into_inner().unwrap() {
        Some(e) => Err(e),
        None => Ok((
            streamed.load(Ordering::Relaxed),
            inserts.load(Ordering::Relaxed),
        )),
    }
}

/// How long to wait for pre-change snapshot holders: the longest a
/// single query can run (a full failover walk of per-attempt request
/// timeouts), floored at one second.
fn reader_drain_wait(cfg: &RouterConfig) -> std::time::Duration {
    cfg.request_timeout
        .saturating_mul(cfg.max_attempts.max(1) as u32)
        .max(std::time::Duration::from_secs(1))
}

/// Wait (bounded) for every query still holding a pre-change
/// membership snapshot to finish. Queries route by the `Arc<RingState>`
/// they loaded, so an in-flight query can still send a key to a member
/// the *new* epoch evicted — the join's drop pass (and the operator
/// stopping a drainee) are only safe once no such reader remains. The
/// snapshot `Arc`s themselves are the tracker: a strong count above
/// ours means a reader still holds one.
fn drain_old_readers(states: &[&Arc<RingState>], max_wait: std::time::Duration) {
    let drained = crate::util::wait::wait_until(max_wait, || {
        states.iter().all(|s| Arc::strong_count(s) == 1)
    });
    if !drained {
        let lingering: usize =
            states.iter().map(|s| Arc::strong_count(s) - 1).sum();
        log::warn!(
            "proceeding with {lingering} reader(s) still on a \
             previous membership snapshot"
        );
    }
}

/// Stream one entity from a current replica to `target`: dump the
/// address list off the first source that answers (sources ordered
/// `prefer` first, then healthy-first in rank order), replay it as
/// retry-idempotent `\x01insert` lines. `Ok(0)` when a source answered
/// and holds nothing (e.g. the key was dynamically deleted) — nothing
/// to move. **Every source failing is an error**: the rebalance must
/// abort rather than complete "ok" with the key unmoved — the later
/// drop pass (or the operator stopping a drainee) would otherwise
/// delete its last copy.
fn handoff(
    backends: &[Arc<Backend>],
    source_set: &[usize],
    prefer: Option<usize>,
    target: &Backend,
    entity: &str,
) -> io::Result<usize> {
    let mut order: Vec<usize> = source_set.to_vec();
    order.sort_by_key(|&i| {
        (Some(i) != prefer, !backends[i].health().is_healthy())
    });
    let mut last_err: Option<io::Error> = None;
    for &s in &order {
        match dump_addresses(&backends[s], entity) {
            Ok(addrs) => {
                let sent = replay_inserts(target, entity, &addrs)?;
                if sent > 0 {
                    // Close the dump→replay window against a concurrent
                    // \x01delete: a delete landing in between is
                    // dual-applied to the target *before* the replayed
                    // entries exist there (a no-op), so the replay would
                    // resurrect the key. Re-dump the source — if the key
                    // is gone there now, undo the replay (idempotent); a
                    // delete landing after this re-check finds the
                    // entries present on the target and removes them via
                    // the dual-write path.
                    if let Ok(now) = dump_addresses(&backends[s], entity) {
                        if now.is_empty() {
                            let _ = target.request(&format!(
                                "{DELETE_REQUEST} {entity}"
                            ));
                            return Ok(0);
                        }
                    }
                }
                return Ok(sent);
            }
            Err(e) => last_err = Some(e),
        }
    }
    match last_err {
        Some(e) => Err(io::Error::other(format!(
            "no source for {entity:?} could be dumped \
             (restore or drain its replicas first): {e}"
        ))),
        None => Ok(0), // empty source set (cannot happen on a ring)
    }
}

/// Surface an `ok:false` control-line reply as an error naming the
/// backend and operation; pass the reply through otherwise. The four
/// wire helpers below share this so the reply shape is interpreted in
/// exactly one place.
fn expect_ok(reply: Json, op: &str, addr: &str) -> io::Result<Json> {
    if reply.get("ok") == Some(&Json::Bool(false)) {
        return Err(io::Error::other(format!(
            "{addr} refused {op}: {}",
            reply
                .get("error")
                .and_then(Json::as_str)
                .unwrap_or("unknown error")
        )));
    }
    Ok(reply)
}

/// `\x01dump` one entity's indexed addresses off `source`.
fn dump_addresses(
    source: &Backend,
    entity: &str,
) -> io::Result<Vec<(u32, u32)>> {
    let reply = source.request(&format!("{DUMP_REQUEST} {entity}"))?;
    let reply = expect_ok(reply, "dump", source.addr())?;
    let Some(arr) = reply.get("addresses").and_then(Json::as_arr) else {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("{} dump reply lacks addresses", source.addr()),
        ));
    };
    let mut out = Vec::with_capacity(arr.len());
    for a in arr {
        match (
            a.get("tree").and_then(Json::as_f64),
            a.get("node").and_then(Json::as_f64),
        ) {
            (Some(t), Some(n)) => out.push((t as u32, n as u32)),
            _ => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("{} dump reply malformed", source.addr()),
                ))
            }
        }
    }
    Ok(out)
}

/// Replay one entity's address list to `target` as `\x01insert` lines.
/// Transport errors retry once (the write path is retry-idempotent —
/// PR 4); an `ok:false` ack is terminal (the target refused the key).
fn replay_inserts(
    target: &Backend,
    entity: &str,
    addrs: &[(u32, u32)],
) -> io::Result<usize> {
    let mut sent = 0usize;
    for &(tree, node) in addrs {
        let line = format!("{INSERT_REQUEST} {tree} {node} {entity}");
        let reply = match target.request(&line) {
            Ok(reply) => reply,
            Err(_) => target.request(&line)?, // idempotent: safe retry
        };
        expect_ok(reply, "insert", target.addr())?;
        sent += 1;
    }
    Ok(sent)
}

/// Install the next epoch's partition on one member
/// (`\x01repartition`).
fn repartition(
    backend: &Backend,
    epoch: u64,
    replicas: usize,
    index: usize,
    addrs: &[String],
) -> io::Result<()> {
    let line = format!(
        "{REPARTITION_REQUEST} {epoch} {replicas} {index} {}",
        addrs.join(",")
    );
    let reply = backend.request(&line)?;
    expect_ok(reply, "repartition", backend.addr())?;
    Ok(())
}

/// Run one member's disowned-key drop pass (`\x01purge`).
fn purge(backend: &Backend) -> io::Result<usize> {
    let reply = backend.request(PURGE_REQUEST)?;
    let reply = expect_ok(reply, "purge", backend.addr())?;
    Ok(reply
        .get("dropped")
        .and_then(Json::as_f64)
        .unwrap_or(0.0) as usize)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn member(addr: &str) -> Arc<Backend> {
        Arc::new(Backend::new(
            0,
            addr,
            &RouterConfig::for_backends([addr]),
            Arc::new(EpochGate::new(0)),
            Arc::new(NetDriver::start().unwrap()),
        ))
    }

    fn membership(addrs: &[&str]) -> Membership {
        let ring = ShardRing::new(addrs.iter().copied());
        let backends = addrs.iter().map(|a| member(a)).collect();
        Membership::new(ring, backends, Arc::new(EpochGate::new(0)))
    }

    #[test]
    fn serving_set_covers_full_index_and_replicated_modes() {
        let ring = ShardRing::new(["a:1", "b:2", "c:3"]);
        let key = entity_key("cardiology");
        assert_eq!(serving_set(&ring, 0, key), vec![0, 1, 2], "R=0 = all");
        assert_eq!(serving_set(&ring, 2, key), ring.replicas(key, 2));
        assert_eq!(
            serving_addrs(&ring, 2, key),
            ring.replicas(key, 2)
                .into_iter()
                .map(|i| ring.name(i).to_string())
                .collect::<Vec<_>>()
        );
    }

    #[test]
    fn membership_pending_commit_and_gate_lifecycle() {
        let m = membership(&["a:1", "b:2"]);
        assert_eq!(m.epoch(), 0);
        assert_eq!(m.load().addresses(), vec!["a:1", "b:2"]);
        assert!(m.gate().accepts(0) && !m.gate().accepts(1));
        assert_eq!(m.probe_targets().len(), 2);

        // opening a pending generation widens the gate and the probe
        // set, but not the serving ring
        let joiner = member("c:3");
        let pending_ring = ShardRing::new(["a:1", "b:2", "c:3"]);
        let mut pending_backends = m.load().backends.clone();
        pending_backends.push(joiner);
        m.set_pending(PendingState {
            ring: pending_ring.clone(),
            backends: pending_backends.clone(),
            epoch: 1,
        });
        assert_eq!(m.epoch(), 0, "queries still route on the old ring");
        assert!(m.gate().accepts(0) && m.gate().accepts(1));
        assert_eq!(m.probe_targets().len(), 3, "the joiner is observed");

        // commit admits the new generation and retires the old epoch
        m.commit(RingState {
            ring: pending_ring,
            backends: pending_backends,
            epoch: 1,
            pending: None,
        });
        assert_eq!(m.epoch(), 1);
        assert_eq!(m.load().addresses(), vec!["a:1", "b:2", "c:3"]);
        assert!(!m.gate().accepts(0), "stale epoch retired");
        assert!(m.load().pending.is_none());
    }

    #[test]
    fn clear_pending_keeps_rolled_members_probeable() {
        let m = membership(&["a:1"]);
        m.set_pending(PendingState {
            ring: ShardRing::new(["a:1", "b:2"]),
            backends: m.load().backends.clone(),
            epoch: 1,
        });
        m.clear_pending();
        assert!(m.load().pending.is_none());
        assert!(
            m.gate().accepts(1),
            "members already rolled to the aborted epoch must not flap"
        );
        assert_eq!(m.epoch(), 0);
    }

    #[test]
    fn report_json_shape() {
        let r = RebalanceReport {
            action: "join",
            addr: "127.0.0.1:7184".into(),
            epoch: 3,
            keys_streamed: 41,
            inserts_sent: 97,
            keys_dropped: 12,
            backends: 4,
        };
        let json = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(json.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(json.get("action").and_then(Json::as_str), Some("join"));
        assert_eq!(json.get("epoch").and_then(Json::as_f64), Some(3.0));
        assert_eq!(
            json.get("keys_streamed").and_then(Json::as_f64),
            Some(41.0)
        );
        assert_eq!(json.get("backends").and_then(Json::as_f64), Some(4.0));
    }

    #[test]
    fn join_rejects_bad_addresses_and_duplicates() {
        let m = Arc::new(membership(&["a:1", "b:2"]));
        let metrics = RouterMetrics::new(2);
        let cfg = RouterConfig::for_backends(["a:1", "b:2"]);
        let vocab = vec!["cardiology".to_string()];
        let driver = Arc::new(NetDriver::start().unwrap());
        let ctx = RebalanceCtx {
            membership: &m,
            metrics: &metrics,
            cfg: &cfg,
            vocab: &vocab,
            replication: 0,
            driver: &driver,
        };
        for bad in ["", "has space:1", "comma,addr:1"] {
            let err = execute_join(&ctx, bad).unwrap_err();
            assert!(err.contains("invalid"), "{bad:?}: {err}");
        }
        // joining an existing member routes to the rejoin path, whose
        // first step is an epoch-gated probe — unreachable here
        let err = execute_join(&ctx, "a:1").unwrap_err();
        assert!(err.contains("cannot rejoin"), "{err}");
        // an unreachable joiner fails before any state changes
        let err = execute_join(&ctx, "127.0.0.1:9").unwrap_err();
        assert!(err.contains("unreachable"), "{err}");
        assert_eq!(m.epoch(), 0);
        assert!(m.load().pending.is_none());
    }

    #[test]
    fn drain_rejects_unknown_members_and_replication_floor() {
        let m = Arc::new(membership(&["a:1", "b:2"]));
        let metrics = RouterMetrics::new(2);
        let cfg = RouterConfig::for_backends(["a:1", "b:2"]);
        let vocab = vec!["cardiology".to_string()];
        let driver = Arc::new(NetDriver::start().unwrap());
        let ctx = RebalanceCtx {
            membership: &m,
            metrics: &metrics,
            cfg: &cfg,
            vocab: &vocab,
            replication: 2,
            driver: &driver,
        };
        let err = execute_drain(&ctx, "nope:9").unwrap_err();
        assert!(err.contains("not in the serving ring"), "{err}");
        let err = execute_drain(&ctx, "a:1").unwrap_err();
        assert!(err.contains("cannot drain below"), "{err}");
        assert_eq!(m.epoch(), 0);
    }
}
