//! Deterministic answer-accuracy judge — the langsmith/doubao stand-in
//! (paper §4.4 uses an LLM scoring framework; see DESIGN.md
//! §Substitutions for why fact-recall preserves the comparison).
//!
//! Accuracy of an answer = fraction of the query's gold facts whose
//! related entity is stated in the answer in relation to its entity.

use crate::data::gold::GoldFact;

/// Judgement for one answer.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Judgement {
    pub gold_total: usize,
    pub gold_recalled: usize,
}

impl Judgement {
    /// Accuracy in [0, 1]; empty gold judges as 1.0 (nothing to miss).
    pub fn accuracy(&self) -> f64 {
        if self.gold_total == 0 {
            1.0
        } else {
            self.gold_recalled as f64 / self.gold_total as f64
        }
    }

    /// Merge (for averaging across a workload).
    pub fn merge(&mut self, other: Judgement) {
        self.gold_total += other.gold_total;
        self.gold_recalled += other.gold_recalled;
    }
}

/// Judge one answer against its gold facts.
///
/// A gold fact (entity, related) counts as recalled when the answer
/// contains a statement linking them (both names present in one
/// sentence-ish window, or an explicit "entity is under related").
pub fn judge(answer: &str, gold: &[GoldFact]) -> Judgement {
    let answer_lc = answer.to_lowercase();
    let sentences: Vec<&str> = answer_lc
        .split(['.', '\n'])
        .filter(|s| !s.trim().is_empty())
        .collect();
    let mut recalled = 0;
    for g in gold {
        let e = g.entity.to_lowercase();
        let r = g.related.to_lowercase();
        let hit = sentences
            .iter()
            .any(|s| s.contains(&e) && s.contains(&r));
        if hit {
            recalled += 1;
        }
    }
    Judgement { gold_total: gold.len(), gold_recalled: recalled }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn g(e: &str, r: &str, d: u8) -> GoldFact {
        GoldFact { entity: e.into(), related: r.into(), distance: d }
    }

    #[test]
    fn full_recall() {
        let gold = vec![g("icu", "cardiology", 1), g("icu", "hospital", 2)];
        let ans = "icu is under cardiology (level 1, tree 0). \
                   icu is under hospital (level 2, tree 0).";
        let j = judge(ans, &gold);
        assert_eq!(j.gold_recalled, 2);
        assert!((j.accuracy() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn partial_recall() {
        let gold = vec![g("icu", "cardiology", 1), g("icu", "hospital", 2)];
        let j = judge("icu is under cardiology.", &gold);
        assert_eq!(j.gold_recalled, 1);
        assert!((j.accuracy() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn requires_same_sentence() {
        let gold = vec![g("icu", "hospital", 2)];
        // both words present but never linked in one sentence
        let j = judge("the icu is busy. the hospital is old.", &gold);
        assert_eq!(j.gold_recalled, 0);
    }

    #[test]
    fn empty_gold_is_perfect() {
        let j = judge("anything", &[]);
        assert!((j.accuracy() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = Judgement { gold_total: 2, gold_recalled: 1 };
        a.merge(Judgement { gold_total: 2, gold_recalled: 2 });
        assert_eq!(a.gold_total, 4);
        assert!((a.accuracy() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn case_insensitive() {
        let gold = vec![g("ICU", "Cardiology", 1)];
        let j = judge("The icu is under cardiology today.", &gold);
        assert_eq!(j.gold_recalled, 1);
    }
}
