"""Kernel-vs-oracle correctness: the CORE L1 signal.

Hypothesis sweeps shapes and dtypes of every Pallas kernel against the
pure-jnp references in kernels/ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.similarity import similarity_scores
from compile.kernels.attention import attention_weights
from compile.kernels.layernorm import layer_norm

jax.config.update("jax_platform_name", "cpu")

FLOAT_DTYPES = [jnp.float32, jnp.bfloat16]


def _arr(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


# ---------------------------------------------------------------------------
# similarity
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 12),
    d=st.sampled_from([8, 32, 64, 96]),
    nblocks=st.integers(1, 5),
    block_n=st.sampled_from([16, 64, 256]),
    dtype_i=st.integers(0, len(FLOAT_DTYPES) - 1),
    seed=st.integers(0, 2**31 - 1),
)
def test_similarity_matches_ref(b, d, nblocks, block_n, dtype_i, seed):
    rng = np.random.default_rng(seed)
    dtype = FLOAT_DTYPES[dtype_i]
    n = nblocks * block_n
    q = _arr(rng, (b, d), dtype)
    docs = _arr(rng, (n, d), dtype)
    got = similarity_scores(q, docs, block_n=block_n)
    want = ref.similarity_scores_ref(q, docs)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_similarity_block_larger_than_n():
    rng = np.random.default_rng(0)
    q = _arr(rng, (4, 16), jnp.float32)
    docs = _arr(rng, (32, 16), jnp.float32)
    got = similarity_scores(q, docs, block_n=256)  # clamps to N
    np.testing.assert_allclose(
        got, ref.similarity_scores_ref(q, docs), rtol=1e-5, atol=1e-5
    )


def test_similarity_rejects_dim_mismatch():
    rng = np.random.default_rng(0)
    with pytest.raises(AssertionError):
        similarity_scores(
            _arr(rng, (2, 8), jnp.float32), _arr(rng, (16, 4), jnp.float32)
        )


def test_similarity_identity_cosine():
    """Normalized vectors scored against themselves give 1.0 diagonals."""
    rng = np.random.default_rng(1)
    x = rng.standard_normal((8, 64)).astype(np.float32)
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    got = np.asarray(similarity_scores(jnp.asarray(x), jnp.asarray(x)))
    np.testing.assert_allclose(np.diag(got), np.ones(8), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 10),
    l=st.sampled_from([4, 16, 64]),
    d=st.sampled_from([8, 64]),
    dtype_i=st.integers(0, len(FLOAT_DTYPES) - 1),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(b, l, d, dtype_i, seed):
    rng = np.random.default_rng(seed)
    dtype = FLOAT_DTYPES[dtype_i]
    q = _arr(rng, (b, d), dtype)
    keys = _arr(rng, (b, l, d), dtype)
    lens = jnp.asarray(rng.integers(0, l + 1, size=(b,)), jnp.int32)
    got = attention_weights(q, keys, lens)
    want = ref.attention_weights_ref(q, keys, lens)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@settings(max_examples=15, deadline=None)
@given(b=st.integers(1, 8), l=st.sampled_from([8, 64]), seed=st.integers(0, 2**31 - 1))
def test_attention_rows_sum_to_one(b, l, seed):
    rng = np.random.default_rng(seed)
    q = _arr(rng, (b, 32), jnp.float32)
    keys = _arr(rng, (b, l, 32), jnp.float32)
    lens = jnp.asarray(rng.integers(1, l + 1, size=(b,)), jnp.int32)
    w = np.asarray(attention_weights(q, keys, lens))
    np.testing.assert_allclose(w.sum(axis=-1), np.ones(b), rtol=1e-5, atol=1e-5)
    # padding positions exactly zero
    for i in range(b):
        assert (w[i, int(lens[i]):] == 0).all()


def test_attention_zero_len_rows_are_zero():
    rng = np.random.default_rng(3)
    q = _arr(rng, (4, 16), jnp.float32)
    keys = _arr(rng, (4, 8, 16), jnp.float32)
    lens = jnp.asarray([0, 3, 0, 8], jnp.int32)
    w = np.asarray(attention_weights(q, keys, lens))
    assert (w[0] == 0).all() and (w[2] == 0).all()
    np.testing.assert_allclose(w[[1, 3]].sum(-1), [1.0, 1.0], rtol=1e-5)


def test_attention_prefers_aligned_key():
    """The key equal to the query must get the largest weight."""
    rng = np.random.default_rng(4)
    q = rng.standard_normal((1, 32)).astype(np.float32) * 3
    keys = rng.standard_normal((1, 8, 32)).astype(np.float32)
    keys[0, 5] = q[0]
    w = np.asarray(
        attention_weights(jnp.asarray(q), jnp.asarray(keys), jnp.asarray([8]))
    )
    assert w[0].argmax() == 5


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 16),
    d=st.sampled_from([8, 64, 128]),
    block_b=st.sampled_from([1, 2, 8]),
    dtype_i=st.integers(0, len(FLOAT_DTYPES) - 1),
    seed=st.integers(0, 2**31 - 1),
)
def test_layernorm_matches_ref(b, d, block_b, dtype_i, seed):
    rng = np.random.default_rng(seed)
    dtype = FLOAT_DTYPES[dtype_i]
    if b % min(block_b, b) != 0:
        b = block_b * max(1, b // block_b)
    x = _arr(rng, (b, d), dtype)
    gamma = _arr(rng, (d,), jnp.float32)
    beta = _arr(rng, (d,), jnp.float32)
    got = layer_norm(x, gamma, beta, block_b=block_b)
    want = ref.layer_norm_ref(x, gamma, beta)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_layernorm_unit_stats():
    """gamma=1, beta=0 output has ~zero mean, ~unit variance per row."""
    rng = np.random.default_rng(5)
    x = _arr(rng, (8, 64), jnp.float32) * 10 + 3
    out = np.asarray(layer_norm(x, jnp.ones(64), jnp.zeros(64)))
    np.testing.assert_allclose(out.mean(axis=-1), np.zeros(8), atol=1e-5)
    np.testing.assert_allclose(out.var(axis=-1), np.ones(8), rtol=1e-3)
