//! Block linked lists of entity addresses (paper §3.1).
//!
//! Every Cuckoo Filter entry points at the head of a *block linked list*
//! holding all addresses of that entity across the forest. Blocks pack
//! several addresses per node, so — versus a classic linked list — the
//! list has far fewer nodes, far less pointer overhead, near-sequential
//! iteration, and O(1) append at the head block. All blocks live in one
//! shared arena (`Vec<Block>`), which removes per-list allocations and
//! the memory fragmentation the paper calls out.

use crate::forest::EntityAddress;

/// Sentinel for "no block".
pub const NIL: u32 = u32::MAX;

/// Addresses per block. 14 × 8 B of payload + len/next keeps a block at
/// 120 B ≈ two cache lines.
pub const BLOCK_CAP: usize = 14;

#[derive(Clone, Debug)]
struct Block {
    addrs: [EntityAddress; BLOCK_CAP],
    len: u8,
    next: u32,
}

impl Block {
    fn empty(next: u32) -> Block {
        Block {
            addrs: [EntityAddress::new(0, 0); BLOCK_CAP],
            len: 0,
            next,
        }
    }
}

/// Arena of blocks shared by every list in one Cuckoo Filter.
#[derive(Clone, Debug, Default)]
pub struct BlockArena {
    blocks: Vec<Block>,
}

impl BlockArena {
    /// New empty arena.
    pub fn new() -> Self {
        Self::default()
    }

    /// Build a list from a slice of addresses; returns the head index
    /// (`NIL` for an empty slice).
    pub fn build(&mut self, addrs: &[EntityAddress]) -> u32 {
        let mut head = NIL;
        for chunk in addrs.rchunks(BLOCK_CAP) {
            let mut b = Block::empty(head);
            b.addrs[..chunk.len()].copy_from_slice(chunk);
            b.len = chunk.len() as u8;
            head = self.blocks.len() as u32;
            self.blocks.push(b);
        }
        head
    }

    /// Append one address, returning the (possibly new) head index.
    /// O(1): fills the head block or prepends a fresh one.
    pub fn push(&mut self, head: u32, addr: EntityAddress) -> u32 {
        if head != NIL {
            let b = &mut self.blocks[head as usize];
            if (b.len as usize) < BLOCK_CAP {
                b.addrs[b.len as usize] = addr;
                b.len += 1;
                return head;
            }
        }
        let mut b = Block::empty(head);
        b.addrs[0] = addr;
        b.len = 1;
        self.blocks.push(b);
        (self.blocks.len() - 1) as u32
    }

    /// Iterate all addresses of a list.
    pub fn iter(&self, head: u32) -> BlockIter<'_> {
        BlockIter { arena: self, block: head, pos: 0 }
    }

    /// Number of addresses in a list (walks the chain).
    pub fn count(&self, head: u32) -> usize {
        let mut n = 0;
        let mut cur = head;
        while cur != NIL {
            let b = &self.blocks[cur as usize];
            n += b.len as usize;
            cur = b.next;
        }
        n
    }

    /// Total blocks allocated (for memory accounting).
    pub fn blocks_allocated(&self) -> usize {
        self.blocks.len()
    }

    /// Approximate heap bytes used by the arena.
    pub fn memory_bytes(&self) -> usize {
        self.blocks.capacity() * std::mem::size_of::<Block>()
    }
}

/// Iterator over one block list.
pub struct BlockIter<'a> {
    arena: &'a BlockArena,
    block: u32,
    pos: usize,
}

impl<'a> Iterator for BlockIter<'a> {
    type Item = EntityAddress;

    fn next(&mut self) -> Option<EntityAddress> {
        while self.block != NIL {
            let b = &self.arena.blocks[self.block as usize];
            if self.pos < b.len as usize {
                let a = b.addrs[self.pos];
                self.pos += 1;
                return Some(a);
            }
            self.block = b.next;
            self.pos = 0;
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addr(i: u32) -> EntityAddress {
        EntityAddress::new(i / 100, i % 100)
    }

    #[test]
    fn build_and_iterate_roundtrip() {
        let mut arena = BlockArena::new();
        let addrs: Vec<EntityAddress> = (0..40).map(addr).collect();
        let head = arena.build(&addrs);
        let got: Vec<EntityAddress> = arena.iter(head).collect();
        assert_eq!(got, addrs);
        assert_eq!(arena.count(head), 40);
    }

    #[test]
    fn empty_list() {
        let mut arena = BlockArena::new();
        let head = arena.build(&[]);
        assert_eq!(head, NIL);
        assert_eq!(arena.count(head), 0);
        assert_eq!(arena.iter(head).count(), 0);
    }

    #[test]
    fn push_fills_head_then_prepends() {
        let mut arena = BlockArena::new();
        let mut head = arena.build(&[addr(0)]);
        for i in 1..BLOCK_CAP as u32 {
            let nh = arena.push(head, addr(i));
            assert_eq!(nh, head, "fills in place until the block is full");
            head = nh;
        }
        assert_eq!(arena.blocks_allocated(), 1);
        head = arena.push(head, addr(99));
        assert_eq!(arena.blocks_allocated(), 2, "new head block");
        assert_eq!(arena.count(head), BLOCK_CAP + 1);
        let got: Vec<EntityAddress> = arena.iter(head).collect();
        assert!(got.contains(&addr(99)));
    }

    #[test]
    fn push_to_nil_starts_list() {
        let mut arena = BlockArena::new();
        let head = arena.push(NIL, addr(7));
        assert_ne!(head, NIL);
        assert_eq!(arena.iter(head).collect::<Vec<_>>(), vec![addr(7)]);
    }

    #[test]
    fn block_packing_density() {
        let mut arena = BlockArena::new();
        let addrs: Vec<EntityAddress> = (0..1000).map(addr).collect();
        arena.build(&addrs);
        let blocks = arena.blocks_allocated();
        // ceil(1000 / 14) = 72
        assert_eq!(blocks, 1000usize.div_ceil(BLOCK_CAP));
    }

    #[test]
    fn many_independent_lists_share_arena() {
        let mut arena = BlockArena::new();
        let h1 = arena.build(&[addr(1), addr(2)]);
        let h2 = arena.build(&[addr(3)]);
        assert_eq!(arena.iter(h1).count(), 2);
        assert_eq!(arena.iter(h2).count(), 1);
        assert_eq!(
            arena.iter(h2).next(),
            Some(addr(3)),
            "lists do not interfere"
        );
    }
}
