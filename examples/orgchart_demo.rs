//! UNHCR-style org-chart scenario (the T-RAG paper's original domain):
//! build the org forest, run all four retrieval algorithms on the same
//! workload, and print the Table-1-style comparison plus a sample answer.
//!
//! Run: `cargo run --release --example orgchart_demo`

use std::sync::Arc;

use cft_rag::bench::harness::{bench, fmt_secs, fmt_speedup, print_table};
use cft_rag::data::corpus::corpus_from_texts;
use cft_rag::data::orgchart::{OrgChartConfig, OrgChartDataset};
use cft_rag::data::workload::{Workload, WorkloadConfig};
use cft_rag::rag::config::{Algorithm, RagConfig};
use cft_rag::rag::pipeline::{make_retriever, RagPipeline};
use cft_rag::runtime::engine::NativeEngine;

fn main() {
    let ds = OrgChartDataset::generate(OrgChartConfig {
        trees: 40,
        ..OrgChartConfig::default()
    });
    let forest = Arc::new(ds.build_forest());
    let stats = forest.stats();
    println!(
        "org forest: {} trees, {} nodes, {} entities, depth {}",
        stats.trees, stats.nodes, stats.distinct_entities, stats.max_depth
    );

    // Compare all four algorithms on one workload.
    let workload = Workload::generate(
        &forest,
        WorkloadConfig { entities_per_query: 5, queries: 50, ..Default::default() },
    );
    let mut rows = Vec::new();
    let mut naive = 0.0;
    for alg in Algorithm::ALL {
        let cfg = RagConfig { algorithm: alg, ..RagConfig::default() };
        let mut r = make_retriever(forest.clone(), &cfg);
        let res = bench(alg.label(), 1, 5, || {
            for q in &workload.queries {
                for e in &q.entities {
                    let _ = r.find(e);
                }
            }
        });
        let mean = res.mean();
        if alg == Algorithm::Naive {
            naive = mean;
        }
        rows.push(vec![
            alg.label().to_string(),
            fmt_secs(mean),
            fmt_speedup(naive, mean),
            format!("{} KiB", r.index_bytes() / 1024),
        ]);
    }
    print_table(
        "org chart — 50-query workload, 5 entities/query",
        &["algorithm", "time_s", "speedup", "index"],
        &rows,
    );

    // One full pipeline answer.
    let mut pipeline = RagPipeline::build(
        forest,
        corpus_from_texts(&ds.documents()),
        Arc::new(NativeEngine::new()),
        RagConfig::default(),
    )
    .expect("pipeline");
    let q = "describe the hierarchy around protection division";
    let resp = pipeline.answer(q).expect("answer");
    println!("\nQ: {q}");
    println!(
        "   {} facts from {} entities in {:?}",
        resp.context.len(),
        resp.entities.len(),
        resp.retrieval_time
    );
    let preview: String = resp.answer.text.chars().take(400).collect();
    println!("A: {preview}...");
}
