//! Arena-allocated entity tree: the hierarchical knowledge structure of
//! Tree-RAG. Nodes carry an `EntityId`; parent/child links are arena
//! indices so traversal is pointer-chasing-free and cache-friendly.

use crate::forest::interner::EntityId;

/// Index of a node within its tree's arena.
pub type NodeIdx = u32;

/// One tree node.
#[derive(Clone, Debug)]
pub struct Node {
    /// The entity at this node.
    pub entity: EntityId,
    /// Parent arena index (`None` for the root).
    pub parent: Option<NodeIdx>,
    /// Child arena indices, in insertion order.
    pub children: Vec<NodeIdx>,
    /// Depth from the root (root = 0).
    pub depth: u32,
}

/// An entity tree.
#[derive(Clone, Debug)]
pub struct Tree {
    nodes: Vec<Node>,
}

impl Tree {
    /// New tree with a root entity.
    pub fn with_root(entity: EntityId) -> Self {
        Tree {
            nodes: vec![Node { entity, parent: None, children: Vec::new(), depth: 0 }],
        }
    }

    /// The root's arena index (always 0).
    pub fn root(&self) -> NodeIdx {
        0
    }

    /// Append a child under `parent`, returning the new node's index.
    pub fn add_child(&mut self, parent: NodeIdx, entity: EntityId) -> NodeIdx {
        let idx = self.nodes.len() as NodeIdx;
        let depth = self.nodes[parent as usize].depth + 1;
        self.nodes.push(Node { entity, parent: Some(parent), children: Vec::new(), depth });
        self.nodes[parent as usize].children.push(idx);
        idx
    }

    /// Node accessor.
    pub fn node(&self, idx: NodeIdx) -> &Node {
        &self.nodes[idx as usize]
    }

    /// Entity at a node.
    pub fn entity(&self, idx: NodeIdx) -> EntityId {
        self.nodes[idx as usize].entity
    }

    /// Number of nodes.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True if only a root exists... never empty by construction.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Max depth over all nodes.
    pub fn max_depth(&self) -> u32 {
        self.nodes.iter().map(|n| n.depth).max().unwrap_or(0)
    }

    /// Iterate arena indices in insertion (BFS-compatible) order.
    pub fn indices(&self) -> impl Iterator<Item = NodeIdx> {
        0..self.nodes.len() as NodeIdx
    }

    /// Leaf count.
    pub fn leaves(&self) -> usize {
        self.nodes.iter().filter(|n| n.children.is_empty()).count()
    }

    /// Iterate all nodes.
    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn e(i: u32) -> EntityId {
        EntityId(i)
    }

    #[test]
    fn build_small_tree() {
        let mut t = Tree::with_root(e(0));
        let a = t.add_child(t.root(), e(1));
        let b = t.add_child(t.root(), e(2));
        let c = t.add_child(a, e(3));
        assert_eq!(t.len(), 4);
        assert_eq!(t.node(a).parent, Some(0));
        assert_eq!(t.node(c).depth, 2);
        assert_eq!(t.node(0).children, vec![a, b]);
        assert_eq!(t.max_depth(), 2);
        assert_eq!(t.leaves(), 2);
    }

    #[test]
    fn entities_accessible() {
        let mut t = Tree::with_root(e(7));
        let a = t.add_child(0, e(9));
        assert_eq!(t.entity(0), e(7));
        assert_eq!(t.entity(a), e(9));
    }
}
